"""Fig. 16 — effect of the task-categorized parallelism allocator: per-GPU
goodput of the EPARA plan vs a no-parallelism deployment (mp=bs=mt=mf=dp=1)
for each of the four categories.  Paper reports 5.9-12.4x (<=1 GPU freq),
1.3-2.5x (>1 GPU freq), 2.3-9.1x (<=1 GPU lat), 2.9-4.5x (>1 GPU lat)."""
from __future__ import annotations

import dataclasses

from repro.core.allocator import ParallelPlan, allocate, plan_goodput
from repro.core.categories import EDGE_P100
from repro.simulator.workload import table1_services

from .common import timed

REPRESENTATIVE = {
    "freq_le1gpu": "mobilenetv2-vid",
    "freq_gt1gpu": "llama3-70b-hci",
    "lat_le1gpu": "resnet50-pic",
    "lat_gt1gpu": "qwen2.5-32b-chat",
}


def run() -> list:
    rows = []
    services = table1_services()
    for label, svc_name in REPRESENTATIVE.items():
        svc = services[svc_name]
        (plan, us) = timed(allocate, svc, EDGE_P100)
        # non-parallelism deployment: the minimum MP that merely FITS the
        # model (no batching / MT / MF / DP) — Fig. 16's comparison point
        from repro.core import costmodel as cm
        naive = dataclasses.replace(plan,
                                    mp=cm.min_mp_for_vram(svc, EDGE_P100),
                                    bs=1, mt=1, mf=1, dp=1)
        g_plan = plan_goodput(svc, EDGE_P100, plan) / max(1, plan.gpus)
        g_naive = plan_goodput(svc, EDGE_P100, naive) / max(1, naive.gpus)
        rows.append((f"allocator_effect/{label}", us,
                     f"{g_plan / max(1e-9, g_naive):.2f}x_per_gpu"))
        rows.append((f"allocator_effect/{label}/plan", us,
                     f"mp{plan.mp}.bs{plan.bs}.mt{plan.mt}"
                     f".mf{plan.mf}.dp{plan.dp}"))
    return rows
