"""Fig. 17d/e + Fig. 19a — information-synchronization overhead and its
effect on offloading precision; error handling.

Paper: sync delay <10 s at (50 Mbps, 100 servers) and (500 Mbps, 1000
servers); mean offload count <1 while sync overhead <100 ms; silent errors
corrected within a cycle; failed servers bypassed."""
from __future__ import annotations

from repro.core.handler import ServerView, ServiceState
from repro.core.sync import RingSynchronizer, sync_round_seconds
from repro.simulator.baselines import make_scheduler
from repro.simulator.engine import SimConfig, Simulation

from .common import testbed_scenario, timed


def run() -> list:
    rows = []
    # Fig. 17d: sync round time under (bandwidth, servers)
    for bw_mbps, n in ((50, 100), (500, 1000), (100, 1000)):
        s = sync_round_seconds(n, 16, bandwidth_gbps=bw_mbps / 1000)
        rows.append((f"sync_overhead/round_{bw_mbps}mbps_n{n}", s * 1e6,
                     f"{s:.3f}s"))
    # Fig. 17e: offload count vs sync interval (stale info => more hops)
    for interval in (0.1, 1.0, 5.0):
        services, servers, events, cfg = testbed_scenario(load=24.0, seed=9)
        cfg.sync_interval_s = interval
        sim = Simulation(servers, services,
                         make_scheduler("EPARA", services, servers[0].gpu),
                         events, cfg)
        r, us = timed(lambda: sim.run())
        rows.append((f"sync_overhead/offloads_sync{interval}s",
                     us / max(1, r.handled), f"{r.mean_offloads:.2f}"))
    # Fig. 19a: corruption + failure resilience
    ring = RingSynchronizer(list(range(8)), interval_s=1.0)
    for sid in range(8):
        ring.publish_local(sid, ServerView(sid=sid, services={
            "svc": ServiceState(theoretical_goodput=10.0)}), 0.0)
    for r_ in range(4):
        ring.step(float(r_))
    ring.corrupt(3)
    bad = ring.views_for(0, 4.0)[3].services["svc"].theoretical_goodput
    ring.publish_local(3, ServerView(sid=3, services={
        "svc": ServiceState(theoretical_goodput=10.0)}), 5.0)
    for r_ in range(4):
        ring.step(5.0 + r_)
    fixed = ring.views_for(0, 9.0)[3].services["svc"].theoretical_goodput
    rows.append(("sync_overhead/corruption_recovered", 0.0,
                 f"{bad:.0f}->{fixed:.0f}"))
    ring.fail(5)
    ring.step(10.0)
    alive = sum(1 for v in ring.views_for(0, 10.0).values() if v.available)
    rows.append(("sync_overhead/failure_bypass", 0.0,
                 f"{alive}_of_7_alive"))
    return rows
