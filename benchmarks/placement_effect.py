"""Fig. 17b — SSSP placement vs LRU / LFU / MFU cache policies (paper: up
to 1.9x goodput), evaluated through the fluid phi on a demand-skewed
scenario, and through full simulation."""
from __future__ import annotations

from repro.core.placement import (evaluate, place_lfu, place_lru, place_mfu,
                                  sssp)
from repro.simulator.baselines import make_scheduler
from repro.simulator.engine import SimConfig, Simulation
from repro.simulator.workload import demand_matrix

from .common import testbed_scenario, timed


def run() -> list:
    rows = []
    services, servers, events, cfg = testbed_scenario(load=30.0, seed=11)
    sched = make_scheduler("EPARA", services, servers[0].gpu)
    demand = demand_matrix(events, services, cfg.horizon_s)
    from repro.core.placement import PlacementProblem
    problem = PlacementProblem(services=services, plans=sched.plans,
                               servers=servers, demand=demand,
                               period_s=cfg.horizon_s)
    theta, us = timed(sssp, problem)
    phi_sssp = evaluate(problem, theta)
    # usage history for the cache policies: total demand per service
    hist = {}
    for (svc, sid), v in demand.items():
        hist[svc] = hist.get(svc, 0.0) + v
    for name, placer in (("LRU", place_lru), ("LFU", place_lfu),
                         ("MFU", place_mfu)):
        phi = evaluate(problem, placer(problem, hist))
        rows.append((f"placement_effect/SSSP_vs_{name}", us,
                     f"{phi_sssp / max(1e-9, phi):.2f}x"))
    rows.append(("placement_effect/sssp_runtime", us, f"{us/1e3:.1f}ms"))
    return rows
