"""Fig. 8 / §4.3 case study — LLMs from chats to robots: EPARA's adaptive
deployment (§4.1) reproduces the paper's per-model operator choices in
spirit: TP for the big latency models, DP for HCI, MT>1 only for the 1.5B."""
from __future__ import annotations

from repro.core.allocator import allocate
from repro.core.categories import EDGE_P100, Sensitivity, ServiceSpec

from .common import timed

# the paper's four-category LLM set (weights bf16; ~256-token responses,
# HCI variants stream ~16-token interactions at >=10 interactions/s)
LLMS = {
    "qwen2.5-1.5b-chat": (1.5, False, 0.0),
    "llama3-8b-chat": (8.0, False, 0.0),
    "dsv2-16b-chat": (16.0, False, 0.0),      # 2.4B active
    "qwen2.5-32b-chat": (32.0, False, 0.0),
    "qwen2.5-1.5b-hci": (1.5, True, 30.0),
    "llama3-8b-hci": (8.0, True, 10.0),
    "dsv2-16b-hci": (16.0, True, 10.0),
    "qwen2.5-32b-hci": (32.0, True, 10.0),
}


def run() -> list:
    rows = []
    for name, (size_b, freq, fps) in LLMS.items():
        active = 2.4 if "dsv2" in name else size_b
        toks = 16 if freq else 256
        svc = ServiceSpec(
            name=name, flops_per_request=2 * active * 1e9 * toks,
            weights_bytes=size_b * 2e9, vram_bytes=size_b * 2e9 * 1.6,
            sensitivity=Sensitivity.FREQUENCY if freq
            else Sensitivity.LATENCY,
            slo_latency_s=0.5 if freq else 2.0, slo_fps=fps)
        plan, us = timed(allocate, svc, EDGE_P100)
        rows.append((f"case_llm/{name}", us,
                     f"mp{plan.mp}.bs{plan.bs}.mt{plan.mt}"
                     f".mf{plan.mf}.dp{plan.dp}.{plan.category}"))
    return rows
