"""Fig. 14 — large-scale goodput vs number of servers (8-GPU servers);
paper claims 1.5-2.0x (latency), 2.8-3.1x (frequency), 1.6-2.4x (mixed)."""
from __future__ import annotations

from repro.core.categories import EDGE_P100, ServerSpec
from repro.simulator.engine import SimConfig, run_comparison
from repro.simulator.workload import (WorkloadConfig, generate_requests,
                                      table1_services)

from .common import Row, timed

BASELINES = ["EPARA", "InterEdge", "AlpaServe", "Galaxy", "SERV-P",
             "USHER", "DeTransformer"]


def run() -> list:
    rows = []
    services = table1_services()
    for n in (4, 8, 16):
        servers = [ServerSpec(sid=i, num_gpus=8, gpu=EDGE_P100)
                   for i in range(n)]
        # per-server demand constant as the cluster scales (Fig. 14's
        # setup); event counts stay linear in n so the Python event loop
        # remains tractable
        wl = WorkloadConfig(horizon_s=20.0, load_scale=40.0, seed=2)
        events = generate_requests(services, n, wl)
        res, us = timed(run_comparison, servers, services, events,
                        BASELINES, SimConfig(horizon_s=20.0))
        ep = res["EPARA"].goodput
        worst = min(res[b].goodput for b in BASELINES[1:])
        best = max(res[b].goodput for b in BASELINES[1:])
        rows.append((f"goodput_scale/n{n}/vs_worst",
                     us / max(1, len(events)),
                     f"{ep / max(1e-9, worst):.2f}x"))
        rows.append((f"goodput_scale/n{n}/vs_best",
                     us / max(1, len(events)),
                     f"{ep / max(1e-9, best):.2f}x"))
    return rows
