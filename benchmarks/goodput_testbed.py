"""Fig. 10/11 — testbed goodput: EPARA vs InterEdge / AlpaServe / Galaxy /
SERV-P on mixed and frequency-heavy workloads.  Paper claims up to 2.1x /
2.2x / 2.5x / 3.2x (mixed) and 1.9x / 2.2x / 2.6x / 3.9x (frequency)."""
from __future__ import annotations

from .common import Row, testbed_scenario, timed
from repro.simulator.engine import run_comparison

BASELINES = ["EPARA", "InterEdge", "AlpaServe", "Galaxy", "SERV-P"]


def run() -> list:
    rows: list = []
    for label, freq_share in (("mixed", 0.5), ("frequency", 0.85)):
        services, servers, events, cfg = testbed_scenario(
            load=45.0, freq_share=freq_share)
        res, us = timed(run_comparison, servers, services, events,
                        BASELINES, cfg)
        ep = res["EPARA"].goodput
        per_req = us / max(1, sum(r.handled for r in res.values()))
        for name in BASELINES[1:]:
            ratio = ep / max(1e-9, res[name].goodput)
            rows.append((f"goodput_testbed/{label}/EPARA_vs_{name}",
                         per_req, f"{ratio:.2f}x"))
        rows.append((f"goodput_testbed/{label}/EPARA_abs",
                     per_req, f"{ep:.0f}req_s"))
        rows.append((f"goodput_testbed/{label}/fulfillment",
                     per_req, f"{res['EPARA'].fulfillment:.3f}"))
    return rows
