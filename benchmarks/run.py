"""Benchmark driver: one module per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run goodput_testbed dp_scaling
  PYTHONPATH=src python -m benchmarks.run --smoke    # tiny CI config
"""
from __future__ import annotations

import os
import sys
import time
import traceback

MODULES = [
    "goodput_testbed",    # Fig. 10/11
    "goodput_scale",      # Fig. 14
    "gpus_needed",        # Fig. 15
    "allocator_effect",   # Fig. 16
    "handler_effect",     # Fig. 17a
    "placement_effect",   # Fig. 17b
    "latency_scaling",    # Fig. 17c + 3e
    "sync_overhead",      # Fig. 17d/e + 19a
    "extreme_cases",      # Fig. 18
    "dp_scaling",         # Fig. 1 / 3a
    "case_study_llm",     # Fig. 8  (§4.3)
    "case_study_seg",     # Fig. 20 (§5.3.4)
    "continuous_batching",  # slot data plane vs batch-sync (this repo)
    "kernel_bench",       # repo-specific
    "roofline_table",     # deliverable (g)
]

# modules cheap enough (and load-bearing enough) for a CI smoke pass
SMOKE_MODULES = ["continuous_batching"]


def main() -> None:
    args = sys.argv[1:]
    if "--smoke" in args:
        args = [a for a in args if a != "--smoke"]
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        wanted = args or SMOKE_MODULES
    else:
        wanted = args or MODULES
    failures = []
    print("name,us_per_call,derived")
    for modname in wanted:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
            rows = mod.run()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001 — report, keep the suite going
            traceback.print_exc()
            failures.append(modname)
        finally:
            dt = time.time() - t0
            print(f"# {modname} done in {dt:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
