"""Fig. 18 — extreme cases: (a) abundant-server scalability with exchange
groups, (c/d) device-saturated registration, (e) GPU-sparse 10x overload
stability (goodput should hold at max feasible, not degrade)."""
from __future__ import annotations

from repro.core.categories import EDGE_P100, ServerSpec
from repro.core.cluster import EdgeCloudControlPlane
from repro.core.sync import sync_round_seconds
from repro.simulator.baselines import make_scheduler
from repro.simulator.engine import SimConfig, Simulation
from repro.simulator.workload import (WorkloadConfig, generate_requests,
                                      table1_services)

from .common import timed


def run() -> list:
    rows = []
    # (a) sync round cost with vs without grouping at large N
    n = 5000
    flat = sync_round_seconds(n, 16, 1.0)
    grouped = sync_round_seconds(500, 16, 1.0)
    rows.append(("extreme/sync_group_speedup", 0.0,
                 f"{flat / grouped:.1f}x"))
    # (c/d) device-saturated registration: model-load queueing
    servers = [ServerSpec(sid=0, num_gpus=2, gpu=EDGE_P100)]
    services = {k: v for k, v in list(table1_services().items())[:3]}
    cp = EdgeCloudControlPlane(servers, services)
    lat = []
    ready = 0.0
    for i in range(40):
        dev = cp.register_device(0, now=0.0)
        svc = list(services)[i % len(services)]
        # single load channel: transfers queue behind each other
        t = max(ready, 0.0)
        done = t + (cp.assign_device_service(dev.did, svc, now=t) - t)
        ready = done
        lat.append(done)
    rows.append(("extreme/device_assign_p50", 0.0,
                 f"{sorted(lat)[len(lat)//2]:.2f}s"))
    rows.append(("extreme/device_assign_p99", 0.0,
                 f"{sorted(lat)[int(len(lat)*0.99)]:.2f}s"))
    # (e) GPU-sparse, 10x overload: goodput stays within 5% of capacity run
    services = table1_services()
    sparse = [ServerSpec(sid=i, num_gpus=1, gpu=EDGE_P100)
              for i in range(2)]
    base_events = generate_requests(
        services, 2, WorkloadConfig(horizon_s=20.0, load_scale=30.0,
                                    seed=13))
    over_events = generate_requests(
        services, 2, WorkloadConfig(horizon_s=20.0, load_scale=300.0,
                                    seed=13))
    cfg = SimConfig(horizon_s=20.0)
    g = servers[0].gpu
    r_base = Simulation(sparse, services,
                        make_scheduler("EPARA", services, g), base_events,
                        cfg).run()
    r_over, us = timed(lambda: Simulation(
        sparse, services, make_scheduler("EPARA", services, g),
        over_events, cfg).run())
    rows.append(("extreme/overload_goodput_retention",
                 us / max(1, r_over.handled),
                 f"{r_over.goodput / max(1e-9, r_base.goodput):.2f}x"))
    return rows
