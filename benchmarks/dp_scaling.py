"""Fig. 1 / Fig. 3a — request-level DP gives ~linear frame-rate scaling
(the paper's 49 fps -> 97 fps with 2 GPUs motivating example), measured
both in the cost model and LIVE on a reduced model with the DP router."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import costmodel as cm
from repro.core.allocator import DPGroupRouter, ParallelPlan
from repro.core.categories import (CAT_FREQ_MULTI, EDGE_P100, Sensitivity,
                                   ServiceSpec)
from repro.simulator.workload import table1_services


def run() -> list:
    rows = []
    # cost-model scaling (the paper's deeplab-video case)
    svc = table1_services()["deeplabv3p-vid"]
    base = cm.throughput(svc, EDGE_P100, batch=4)
    for dp in (1, 2, 4):
        plan = ParallelPlan(service=svc.name, category=CAT_FREQ_MULTI,
                            bs=4, dp=dp)
        from repro.core.allocator import plan_goodput
        fps = plan_goodput(svc, EDGE_P100, plan)
        rows.append((f"dp_scaling/model_dp{dp}", 0.0,
                     f"{fps / base:.2f}x"))
    # live: round-robin frames across dp "groups" of a reduced model;
    # each group is an independent jit'd decode stream
    from repro.configs import get_config, reduced
    from repro.models.registry import model_api
    cfg = reduced(get_config("minicpm-2b"))
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = np.arange(8, dtype=np.int32)[None]
    import jax.numpy as jnp

    def frame_fn(p, t):   # one "frame" = one prefill pass
        h, _ = api.forward_hidden(p, cfg, {"tokens": t})
        return api.logits_fn(p, cfg, h[:, -1])

    jf = jax.jit(frame_fn)
    jf(params, jnp.asarray(tokens)).block_until_ready()
    n_frames = 24
    t0 = time.perf_counter()
    for _ in range(n_frames):
        jf(params, jnp.asarray(tokens)).block_until_ready()
    fps1 = n_frames / (time.perf_counter() - t0)
    # dp=2: alternate frames between two replicas (single host: models the
    # dispatch path; real speedup comes from distinct devices)
    router = DPGroupRouter(ParallelPlan(service="x",
                                        category=CAT_FREQ_MULTI, dp=2))
    groups = [params, jax.tree.map(lambda a: a + 0, params)]
    t0 = time.perf_counter()
    for i in range(n_frames):
        g = router.route()
        jf(groups[g], jnp.asarray(tokens)).block_until_ready()
    fps2 = n_frames / (time.perf_counter() - t0)
    rows.append(("dp_scaling/live_router_overhead", 1e6 / fps1,
                 f"{fps2 / fps1:.2f}x_single_host"))
    return rows
