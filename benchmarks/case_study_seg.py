"""Fig. 20 / §5.3.4 case study 2 — segmentation models across the four
categories (Table 2): Unet/DeepLabV3+/SCTNet (<=1 GPU), MaskFormer/OMG-Seg
(>1 GPU), picture (latency) and 60fps-1080p video (frequency)."""
from __future__ import annotations

from repro.core.allocator import allocate, plan_goodput
from repro.core.categories import EDGE_P100, Sensitivity, ServiceSpec

from .common import timed

SEG = {
    # name: (gflops/frame at 1080p, params M, video?)
    "unet": (120.0, 31.0, False),
    "deeplabv3p": (380.0, 62.7, False),
    "sctnet": (180.0, 17.4, False),
    "maskformer": (700.0, 10_500.0, False),
    "omgseg": (1400.0, 19_000.0, False),
    "unet-vid": (120.0, 31.0, True),
    "deeplabv3p-vid": (380.0, 62.7, True),
    "sctnet-vid": (180.0, 17.4, True),
}


def run() -> list:
    rows = []
    for name, (gf, pm, vid) in SEG.items():
        svc = ServiceSpec(
            name=name, flops_per_request=gf * 1e9,
            weights_bytes=pm * 2e6, vram_bytes=pm * 2e6 * 2.5 + 2e9,
            sensitivity=Sensitivity.FREQUENCY if vid
            else Sensitivity.LATENCY,
            slo_latency_s=0.2 if vid else 0.8,
            slo_fps=60.0 if vid else 0.0)
        plan, us = timed(allocate, svc, EDGE_P100)
        fps = plan_goodput(svc, EDGE_P100, plan)
        tag = "fps" if vid else "req_s"
        rows.append((f"case_seg/{name}", us,
                     f"mp{plan.mp}.bs{plan.bs}.mf{plan.mf}.dp{plan.dp}"
                     f"={fps:.0f}{tag}"))
    return rows
