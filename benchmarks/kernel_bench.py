"""Kernel micro-benchmarks: wall time of the memory-bounded jnp oracles
(XLA-compiled; the TPU path is the Pallas kernel, validated in interpret
mode by tests) plus derived FLOP/s, at serving-representative shapes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.quant import QuantPages, quantize


def _bench(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    # flash attention prefill (B=1, L=2048, GQA 8/2)
    B, L, Hq, Hkv, D = 1, 2048, 8, 2, 64
    q = jax.random.normal(key, (B, L, Hq, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, L, Hkv, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, L, Hkv, D), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _bench(f, q, k, v)
    flops = 4 * B * Hq * L * L * D / 2      # causal half
    rows.append(("kernel/flash_prefill_2k", us,
                 f"{flops / (us * 1e-6) / 1e9:.1f}GFLOPs"))
    # decode vs 32k cache
    S = 32768
    qd = jax.random.normal(key, (4, Hq, D), jnp.bfloat16)
    kc = jax.random.normal(key, (4, S, Hkv, D), jnp.bfloat16)
    vc = jax.random.normal(key, (4, S, Hkv, D), jnp.bfloat16)
    fd = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v, S))
    us = _bench(fd, qd, kc, vc)
    bytes_ = 2 * 4 * S * Hkv * D * 2
    rows.append(("kernel/decode_32k", us,
                 f"{bytes_ / (us * 1e-6) / 1e9:.1f}GB_s"))
    # SSD chunked scan (mamba2-ish slice)
    Bb, Lx, H, P, N = 2, 2048, 8, 64, 64
    x = jax.random.normal(key, (Bb, Lx, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (Bb, Lx, H)))
    A = -jnp.exp(jax.random.normal(key, (H,)))
    Bm = jax.random.normal(key, (Bb, Lx, 1, N))
    C = jax.random.normal(key, (Bb, Lx, 1, N))
    fs = jax.jit(lambda *a: ref.ssd_chunked_ref(*a, chunk=128)[0])
    us = _bench(fs, x, dt, A, Bm, C)
    rows.append(("kernel/ssd_2k", us,
                 f"{Bb * Lx * H / (us * 1e-6) / 1e6:.2f}Mtok_heads_s"))
    # paged decode, bf16 vs int8 pools (same table/lens; the int8 case
    # streams half the K/V bytes plus the f32 per-row scales and
    # dequantizes in-register — the serving arena's quantized hot path)
    bs, P = 32, 64 * 4 + 1                 # 4 slots x 64 blocks + trash
    Bp = 4
    kp = jax.random.normal(key, (P, bs, Hkv, D), jnp.bfloat16)
    vp = jax.random.normal(key, (P, bs, Hkv, D), jnp.bfloat16)
    bt = jnp.arange(Bp * 64, dtype=jnp.int32).reshape(Bp, 64)
    cl = jnp.full((Bp,), 64 * bs, jnp.int32)
    qp = jax.random.normal(key, (Bp, Hq, D), jnp.bfloat16)
    fp = jax.jit(lambda q, k, v: ops.paged_decode_attention(
        q, k, v, bt, cl, impl="ref"))
    us = _bench(fp, qp, kp, vp)
    kv_bytes = 2 * Bp * 64 * bs * Hkv * D * 2
    rows.append(("kernel/paged_decode_bf16", us,
                 f"{kv_bytes / (us * 1e-6) / 1e9:.1f}GB_s"))
    kq = QuantPages(*quantize(kp))
    vq = QuantPages(*quantize(vp))
    fq = jax.jit(lambda q, k, v: ops.paged_decode_attention(
        q, k, v, bt, cl, impl="ref"))
    us = _bench(fq, qp, kq, vq)
    kv_bytes = 2 * Bp * 64 * bs * Hkv * (D * 1 + 4)   # int8 rows + scales
    rows.append(("kernel/paged_decode_int8", us,
                 f"{kv_bytes / (us * 1e-6) / 1e9:.1f}GB_s"))
    # grouped expert GEMM
    E, Cc, K, Nn = 8, 512, 1024, 1024
    lhs = jax.random.normal(key, (E, Cc, K), jnp.bfloat16)
    rhs = jax.random.normal(key, (E, K, Nn), jnp.bfloat16)
    fg = jax.jit(ref.grouped_matmul_ref)
    us = _bench(fg, lhs, rhs)
    flops = 2 * E * Cc * K * Nn
    rows.append(("kernel/moe_gemm", us,
                 f"{flops / (us * 1e-6) / 1e9:.1f}GFLOPs"))
    return rows
