"""Fig. 17a — effect of request handling: EPARA vs a first-hop-only variant
(no offloading).  Paper: 2.2-2.4x (<=1 GPU) and 2.9-3.1x (>1 GPU)."""
from __future__ import annotations

import dataclasses

from repro.simulator.baselines import EparaScheduler, Route, make_scheduler
from repro.core.handler import Outcome
from repro.simulator.engine import SimConfig, Simulation

from .common import testbed_scenario, timed


class _NoOffload(EparaScheduler):
    name = "EPARA-first-hop-only"

    def route(self, req, sid, now, ctx):
        d = super().route(req, sid, now, ctx)
        if d.outcome == Outcome.OFFLOAD:
            return Route(Outcome.INSUFFICIENT)
        return d


def run() -> list:
    rows = []
    # skew arrivals: half the servers receive 4x the load so local-only
    # saturates while the cluster has idle capacity elsewhere
    services, servers, events, cfg = testbed_scenario(load=40.0, seed=7,
                                                      skew=0.8)
    skewed = []
    for t, sid, r in events:
        sid2 = sid % 3          # concentrate on 3 of 6 servers
        skewed.append((t, sid2, r))
    ep = Simulation(servers, services,
                    make_scheduler("EPARA", services, servers[0].gpu),
                    skewed, cfg)
    r_ep, us = timed(lambda: ep.run())
    noof = Simulation(servers, services,
                      _NoOffload(services, servers[0].gpu), skewed, cfg)
    r_no = noof.run()
    rows.append(("handler_effect/with_vs_without_offload",
                 us / max(1, r_ep.handled),
                 f"{r_ep.goodput / max(1e-9, r_no.goodput):.2f}x"))
    rows.append(("handler_effect/mean_offload_count",
                 us / max(1, r_ep.handled),
                 f"{r_ep.mean_offloads:.2f}"))
    return rows
