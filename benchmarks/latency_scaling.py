"""Fig. 17c + Fig. 3e — scheduling latency vs number of servers.

Paper: EPARA handler <20 ms at 10k nodes and one SSSP placement <200 ms
under 10k servers (with CELF + grouping), while centralized schemes blow
past 100 ms at 10 servers / 750 ms at 30+."""
from __future__ import annotations

import time

from repro.core.allocator import allocate
from repro.core.categories import EDGE_P100, Request, ServerSpec, ServiceSpec
from repro.core.handler import RequestHandler, ServerView, ServiceState
from repro.core.placement import PlacementProblem, sssp
from repro.simulator.baselines import make_scheduler
from repro.simulator.workload import table1_services


def _handler_latency(n_servers: int, reps: int = 200) -> float:
    svc = ServiceSpec("svc", flops_per_request=1e9, weights_bytes=1e8,
                      vram_bytes=2e8, slo_latency_s=1.0)
    h = RequestHandler(0)
    peers = {i: ServerView(sid=i, services={
        "svc": ServiceState(theoretical_goodput=10.0)}, sync_age_s=0.1)
        for i in range(1, n_servers)}
    local = ServerView(sid=0, services={
        "svc": ServiceState(theoretical_goodput=0.0, queue_time_s=99.0)})
    req = Request(rid=1, service="svc", arrival_s=0.0, deadline_s=10.0)
    t0 = time.perf_counter()
    for _ in range(reps):
        h.handle(req, 0.1, svc, local, peers)
    return (time.perf_counter() - t0) / reps * 1e3     # ms


def _placement_latency(n_servers: int, group: int = 250) -> float:
    """One placement round with the paper's §5.3.2 grouping fix: servers in
    exchange groups of <=250, solved independently (CELF within groups)."""
    services = {k: v for k, v in list(table1_services().items())[:6]}
    plans = {n: allocate(s, EDGE_P100) for n, s in services.items()}
    t0 = time.perf_counter()
    for start in range(0, n_servers, group):
        size = min(group, n_servers - start)
        servers = [ServerSpec(sid=i, num_gpus=1, gpu=EDGE_P100)
                   for i in range(size)]
        demand = {(l, i): 3.0 for l in services for i in range(size)}
        problem = PlacementProblem(services=services, plans=plans,
                                   servers=servers, demand=demand,
                                   period_s=60.0)
        sssp(problem, lazy=True)
    return (time.perf_counter() - t0) * 1e3            # ms


def run() -> list:
    rows = []
    for n in (10, 100, 1000, 10_000):
        ms = _handler_latency(n)
        rows.append((f"latency_scaling/handler_n{n}", ms * 1e3,
                     f"{ms:.2f}ms"))
    for n in (10, 100, 1000):
        ms = _placement_latency(n)
        rows.append((f"latency_scaling/placement_n{n}", ms * 1e3,
                     f"{ms:.1f}ms"))
    # groups are independent (one controller each): wall time at any scale
    # = one group's solve — the paper's <200 ms at 10k servers
    per_group = _placement_latency(250)
    rows.append(("latency_scaling/placement_per_group_10k", per_group * 1e3,
                 f"{per_group:.1f}ms_parallelizable"))
    # centralized comparison (Fig. 3e model): ungrouped NP-ish solve cost
    # ~1e-3*n^2 s: >100 ms at 10 servers, >750 ms at 30+ (paper's curve);
    # SERV-P survives in §5.2 only by grouping servers into tens
    for n in (10, 30, 100):
        rows.append((f"latency_scaling/centralized_ungrouped_n{n}", 0.0,
                     f"{1e-3 * n * n * 1e3:.0f}ms"))
    sp = make_scheduler("SERV-P", table1_services(), EDGE_P100)
    rows.append(("latency_scaling/servp_grouped", 0.0,
                 f"{sp.scheduling_latency(100)*1e3:.0f}ms"))
    return rows
