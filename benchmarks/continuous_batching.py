"""Continuous batching vs batch-sync serving: the slot data plane's win.

Two comparisons on the paper's bursty mixed-``max_new_tokens`` workloads:

1. **Live engine** (toy dense model on CPU): the same request set served
   by ``ServiceRuntime(mode="continuous")`` and ``mode="sync"``.  The
   derived column reports fused decode steps — the hardware-independent
   cost the slot loop minimizes (short requests stop burning steps after
   EOS / their own budget, late arrivals join mid-decode instead of
   waiting for the batch to drain).

2. **Simulator** (testbed scale): goodput of the event-driven simulator
   under ``serving_mode="continuous"`` vs ``"sync"`` batch barriers, so
   the co-simulation's admission model matches whichever live engine mode
   is deployed.

Smoke mode (REPRO_BENCH_SMOKE=1 or ``python -m benchmarks.run --smoke``)
shrinks both to a few seconds.
"""
from __future__ import annotations

import os

import numpy as np

from .common import Row, timed


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"


def _toy_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="toy", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=257, dtype="float32",
                       param_dtype="float32")


def _bursty_requests(n, rng, vocab):
    """The paper's bursty shape: waves of short requests with a straggler
    (long max_new) at the head of each wave."""
    from repro.serving.engine import GenerationRequest
    reqs = []
    for i in range(n):
        long = i % 4 == 0
        reqs.append(GenerationRequest(
            rid=i, tokens=rng.integers(1, vocab, 5).astype(np.int32),
            max_new_tokens=16 if long else 2, stream=i))
    return reqs


def _live_engine_rows() -> list:
    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.serving.engine import ServiceRuntime

    cfg = _toy_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    plan = ParallelPlan(service="bench",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=4)
    n = 8 if _smoke() else 24
    rows = []
    steps = {}
    for mode in ("continuous", "sync"):
        rng = np.random.default_rng(0)
        rt = ServiceRuntime(cfg, params, plan, mode=mode)
        for r in _bursty_requests(n, rng, cfg.vocab_size):
            rt.submit(r)
        res, us = timed(rt.drain)
        assert len(res) == n
        toks = sum(len(r.tokens) for r in res)
        steps[mode] = rt.decode_steps
        rows.append((f"serve_{mode}", us,
                     f"decode_steps={rt.decode_steps};tokens={toks}"))
    assert steps["continuous"] < steps["sync"], steps
    rows.append(("serve_step_saving", 0.0,
                 f"{steps['sync'] - steps['continuous']}"
                 f"/{steps['sync']}_steps_saved"))
    return rows


def _simulator_rows() -> list:
    import dataclasses

    from repro.simulator.engine import run_comparison

    from .common import testbed_scenario

    horizon = 10.0 if _smoke() else 40.0
    load = 10.0 if _smoke() else 30.0
    services, servers, events, cfg = testbed_scenario(horizon=horizon,
                                                      load=load, seed=3)
    rows = []
    for mode in ("continuous", "sync"):
        c = dataclasses.replace(cfg, serving_mode=mode)
        out, us = timed(run_comparison, servers, services, events,
                        ["EPARA"], c)
        r = out["EPARA"]
        rows.append((f"sim_{mode}", us,
                     f"goodput={r.goodput:.2f};fulfillment="
                     f"{r.fulfillment:.3f}"))
    return rows


def run() -> list:
    rows: list = []
    rows.extend(_live_engine_rows())
    rows.extend(_simulator_rows())
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
