"""Serving data planes compared: paged KV arena vs dense merge vs sync.

Three comparisons on the paper's bursty mixed-``max_new_tokens`` workloads:

1. **Live engine** (toy dense model on CPU): the same request set served
   by the paged arena (``kvcache_impl="paged"``), the dense merge path
   (``"dense"``), and the run-to-completion baseline (``mode="sync"``).
   Derived columns report the hardware-independent costs each layer
   removes: fused decode steps (slot loop vs barrier), **decode
   compilations** (the arena's fixed ``(capacity, ...)`` shape compiles
   once; the dense path retraces whenever the live batch size changes)
   and **admission-copy bytes / whole-cache copies** (arena admissions
   scatter only the new request's pages; dense admissions re-materialize
   the entire live batch through ``kvcache.merge``).

2. **Acceptance checks**: the paged engine must admit mid-decode with
   ZERO whole-cache copies and at most one decode compilation, while
   matching the dense engine's greedy tokens exactly.

3. **Simulator** (testbed scale): goodput of the event-driven simulator
   under ``serving_mode`` paged / continuous / sync with a non-zero
   ``admission_copy_s``, so the co-simulation's admission model matches
   whichever live data plane is deployed.

Smoke mode (REPRO_BENCH_SMOKE=1 or ``python -m benchmarks.run --smoke``)
shrinks both to a few seconds.
"""
from __future__ import annotations

import os

import numpy as np

from .common import Row, timed


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"


def _toy_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="toy", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=257, dtype="float32",
                       param_dtype="float32")


def _bursty_requests(n, rng, vocab):
    """The paper's bursty shape: waves of short requests with a straggler
    (long max_new) at the head of each wave."""
    from repro.serving.engine import GenerationRequest
    reqs = []
    for i in range(n):
        long = i % 4 == 0
        reqs.append(GenerationRequest(
            rid=i, tokens=rng.integers(1, vocab, 5).astype(np.int32),
            max_new_tokens=16 if long else 2, stream=i))
    return reqs


def _live_engine_rows() -> list:
    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.serving.engine import ServiceRuntime

    cfg = _toy_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    plan = ParallelPlan(service="bench",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=4)
    n = 8 if _smoke() else 24
    variants = (("paged", "continuous"), ("dense", "continuous"),
                ("dense", "sync"))
    rows, steps, traces, tokens = [], {}, {}, {}
    for kv, mode in variants:
        name = f"serve_{mode}_{kv}" if mode == "continuous" \
            else f"serve_{mode}"
        rng = np.random.default_rng(0)
        rt = ServiceRuntime(cfg, params, plan, mode=mode, kvcache_impl=kv,
                            max_seq_len=64, block_size=16)
        for r in _bursty_requests(n, rng, cfg.vocab_size):
            rt.submit(r)
        res, us = timed(rt.drain)
        assert len(res) == n
        toks = sum(len(r.tokens) for r in res)
        steps[(kv, mode)] = rt.decode_steps
        traces[(kv, mode)] = rt.decode_traces
        tokens[(kv, mode)] = {r.rid: tuple(r.tokens) for r in res}
        rows.append((name, us,
                     f"decode_steps={rt.decode_steps};"
                     f"decode_compiles={rt.decode_traces};"
                     f"whole_cache_copies={rt.whole_cache_copies};"
                     f"admission_copy_kb={rt.admission_copy_bytes // 1024};"
                     f"tokens={toks}"))
        if (kv, mode) == ("paged", "continuous"):
            # acceptance: zero-copy admissions + one compile, ever
            assert rt.whole_cache_copies == 0, rt.whole_cache_copies
            assert rt.decode_traces <= 1, rt.decode_traces
            paged_copy_kb = rt.admission_copy_bytes // 1024
        elif (kv, mode) == ("dense", "continuous"):
            assert rt.whole_cache_copies > 0   # every merge copies the batch
            assert rt.decode_traces > traces[("paged", "continuous")]
            dense_copy_kb = rt.admission_copy_bytes // 1024
    # acceptance: paged greedy tokens == dense greedy tokens, exactly
    assert tokens[("paged", "continuous")] == tokens[("dense", "continuous")]
    assert steps[("paged", "continuous")] < steps[("dense", "sync")]
    rows.append(("serve_step_saving", 0.0,
                 f"{steps[('dense', 'sync')] - steps[('paged', 'continuous')]}"
                 f"/{steps[('dense', 'sync')]}_steps_saved"))
    rows.append(("serve_admission_copy_saving", 0.0,
                 f"{dense_copy_kb - paged_copy_kb}/{dense_copy_kb}"
                 f"_kb_not_copied"))
    return rows


def _simulator_rows() -> list:
    import dataclasses

    from repro.simulator.engine import run_comparison

    from .common import testbed_scenario

    horizon = 10.0 if _smoke() else 40.0
    load = 10.0 if _smoke() else 30.0
    services, servers, events, cfg = testbed_scenario(horizon=horizon,
                                                      load=load, seed=3)
    rows = []
    goodput = {}
    for mode in ("paged", "continuous", "sync"):
        c = dataclasses.replace(cfg, serving_mode=mode,
                                admission_copy_s=0.01)
        out, us = timed(run_comparison, servers, services, events,
                        ["EPARA"], c)
        r = out["EPARA"]
        goodput[mode] = r.goodput
        rows.append((f"sim_{mode}", us,
                     f"goodput={r.goodput:.2f};fulfillment="
                     f"{r.fulfillment:.3f}"))
    # paged removes the per-admission copy stall, so its goodput must not
    # trail continuous (deterministic since SSSP's equal-gain tiebreak is
    # value-based; see core/placement.py)
    assert goodput["paged"] >= goodput["continuous"], goodput
    return rows


def run() -> list:
    rows: list = []
    rows.extend(_live_engine_rows())
    rows.extend(_simulator_rows())
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
