"""Serving data planes compared: paged KV arena vs dense merge vs sync,
plus the zero-gather decode telemetry (``BENCH_decode.json``).

Comparisons on the paper's bursty mixed-``max_new_tokens`` workloads:

1. **Live engine** (toy dense model on CPU): the same request set served
   by the paged arena (``kvcache_impl="paged"``), the dense merge path
   (``"dense"``), and the run-to-completion baseline (``mode="sync"``).
   Derived columns report the hardware-independent costs each layer
   removes: fused decode steps (slot loop vs barrier), **decode
   compilations** (the arena's fixed ``(capacity, ...)`` shape compiles
   once; the dense path retraces whenever the live batch size changes)
   and **admission-copy bytes / whole-cache copies** (arena admissions
   scatter only the new request's pages; dense admissions re-materialize
   the entire live batch through ``kvcache.merge``).

2. **Acceptance checks**: the paged engine must admit mid-decode with
   ZERO whole-cache copies and at most one decode compilation, while
   matching the dense engine's greedy tokens exactly.

3. **Simulator** (testbed scale): goodput of the event-driven simulator
   under ``serving_mode`` paged / continuous / sync with a non-zero
   ``admission_copy_s``, so the co-simulation's admission model matches
   whichever live data plane is deployed.

Smoke mode (REPRO_BENCH_SMOKE=1 or ``python -m benchmarks.run --smoke``)
shrinks both to a few seconds.
"""
from __future__ import annotations

import os

import numpy as np

from .common import Row, StepStatsAggregator, append_dated_entry, timed


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"


def _toy_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="toy", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=257, dtype="float32",
                       param_dtype="float32")


def _bursty_requests(n, rng, vocab):
    """The paper's bursty shape: waves of short requests with a straggler
    (long max_new) at the head of each wave."""
    from repro.serving.engine import GenerationRequest
    reqs = []
    for i in range(n):
        long = i % 4 == 0
        reqs.append(GenerationRequest(
            rid=i, tokens=rng.integers(1, vocab, 5).astype(np.int32),
            max_new_tokens=16 if long else 2, stream=i))
    return reqs


def _live_engine_rows() -> list:
    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.serving.engine import ServiceRuntime

    cfg = _toy_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    plan = ParallelPlan(service="bench",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=4)
    n = 8 if _smoke() else 24
    variants = (("paged", "continuous"), ("dense", "continuous"),
                ("dense", "sync"))
    rows, steps, traces, tokens = [], {}, {}, {}
    for kv, mode in variants:
        name = f"serve_{mode}_{kv}" if mode == "continuous" \
            else f"serve_{mode}"
        rng = np.random.default_rng(0)
        rt = ServiceRuntime(cfg, params, plan, mode=mode, kvcache_impl=kv,
                            max_seq_len=64, block_size=16)
        for r in _bursty_requests(n, rng, cfg.vocab_size):
            rt.submit(r)
        res, us = timed(rt.drain)
        assert len(res) == n
        toks = sum(len(r.tokens) for r in res)
        steps[(kv, mode)] = rt.decode_steps
        traces[(kv, mode)] = rt.decode_traces
        tokens[(kv, mode)] = {r.rid: tuple(r.tokens) for r in res}
        rows.append((name, us,
                     f"decode_steps={rt.decode_steps};"
                     f"decode_compiles={rt.decode_traces};"
                     f"whole_cache_copies={rt.whole_cache_copies};"
                     f"admission_copy_kb={rt.admission_copy_bytes // 1024};"
                     f"tokens={toks}"))
        if (kv, mode) == ("paged", "continuous"):
            # acceptance: zero-copy admissions + one compile, ever
            assert rt.whole_cache_copies == 0, rt.whole_cache_copies
            assert rt.decode_traces <= 1, rt.decode_traces
            paged_copy_kb = rt.admission_copy_bytes // 1024
        elif (kv, mode) == ("dense", "continuous"):
            assert rt.whole_cache_copies > 0   # every merge copies the batch
            assert rt.decode_traces > traces[("paged", "continuous")]
            dense_copy_kb = rt.admission_copy_bytes // 1024
    # acceptance: paged greedy tokens == dense greedy tokens, exactly
    assert tokens[("paged", "continuous")] == tokens[("dense", "continuous")]
    assert steps[("paged", "continuous")] < steps[("dense", "sync")]
    rows.append(("serve_step_saving", 0.0,
                 f"{steps[('dense', 'sync')] - steps[('paged', 'continuous')]}"
                 f"/{steps[('dense', 'sync')]}_steps_saved"))
    rows.append(("serve_admission_copy_saving", 0.0,
                 f"{dense_copy_kb - paged_copy_kb}/{dense_copy_kb}"
                 f"_kb_not_copied"))
    return rows


def _chunked_prefill_rows() -> list:
    """Chunked vs unchunked prefill on a long-prompt-mid-decode workload.

    Acceptance (asserted):
      * identical greedy tokens;
      * chunked prefill compiles at most ``len(chunk_buckets)`` traces;
      * lower max per-step stall (time-to-next-token for live decode
        slots) than unchunked when the long prompt arrives mid-decode.
    """
    import gc

    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serving.engine import GenerationRequest, ServiceRuntime

    # the prompt/chunk asymmetry must be large enough that prefill
    # COMPUTE dominates the per-chunk dispatch overhead (gather/scatter
    # of the slot view — the part the Pallas block-table chunk kernel
    # removes on TPU): 480-token prompt vs 32-token chunks
    cfg = ModelConfig(name="bench", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=32,
                      d_ff=128, vocab_size=257, dtype="float32",
                      param_dtype="float32")
    params = T.init(jax.random.PRNGKey(0), cfg)
    plan = ParallelPlan(service="bench",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=4)
    long_len, chunk = 480, 32
    short_len, short_new = 5, 12 if _smoke() else 20
    repeats = 2 if _smoke() else 3

    def _reqs(rid0, rng):
        shorts = [GenerationRequest(
            rid=rid0 + i,
            tokens=rng.integers(1, cfg.vocab_size, short_len)
            .astype(np.int32), max_new_tokens=short_new) for i in range(3)]
        longr = GenerationRequest(
            rid=rid0 + 3,
            tokens=rng.integers(1, cfg.vocab_size, long_len)
            .astype(np.int32), max_new_tokens=4)
        return shorts, longr

    def _measure(chunked):
        rt = ServiceRuntime(cfg, params, plan, kvcache_impl="paged",
                            max_seq_len=512, block_size=32,
                            chunked_prefill=chunked, prefill_chunk=chunk)
        tokens = {}
        # repeat 0 doubles as compile warmup (same shapes throughout).
        # Stall = wall time of steps that decode live slots WHILE
        # absorbing long-prompt prefill work; per repeat we keep the
        # SECOND-largest such step (one scheduler/GC hiccup forgiven —
        # unchunked has a single prefill-bearing step, so its max stands)
        # and take the min across repeats.
        stalls = []
        for rep in range(repeats + 1):
            rng = np.random.default_rng(7)      # identical workload per rep
            shorts, longr = _reqs(rep * 10, rng)
            for r in shorts:
                rt.submit(r)
            rt.step(); rt.step()                # shorts are decoding
            rt.submit(longr)                    # long prompt mid-decode
            agg = StepStatsAggregator()
            gc.collect()                        # GC pauses masquerade as
            gc.disable()                        # multi-ms step stalls
            try:
                agg.drain(rt)
            finally:
                gc.enable()
            busy = [dt for dt, st in agg.timed_steps
                    if st.decode_steps and (st.prefill_chunk_tokens
                                            or st.admitted)]
            tokens.update({r.rid % 10: tuple(r.tokens)
                           for r in agg.results})
            if rep > 0:                         # skip the compile rep
                stalls.append(sorted(busy)[-2] if len(busy) > 1
                              else max(busy))
        return tokens, min(stalls), rt

    toks_c, stall_c, rt_c = _measure(True)
    toks_u, stall_u, rt_u = _measure(False)
    # acceptance: same greedy tokens, bounded compiles, smaller stall
    assert toks_c == toks_u
    assert rt_c.prefill_traces <= len(rt_c.chunk_buckets), \
        (rt_c.prefill_traces, rt_c.chunk_buckets)
    assert stall_c < stall_u, (stall_c, stall_u)
    return [
        ("serve_chunked_prefill", stall_c * 1e6,
         f"max_step_stall_ms={stall_c * 1e3:.2f};prefill_compiles="
         f"{rt_c.prefill_traces};buckets={len(rt_c.chunk_buckets)};"
         f"chunk_calls={rt_c.prefill_chunk_calls}"),
        ("serve_unchunked_prefill", stall_u * 1e6,
         f"max_step_stall_ms={stall_u * 1e3:.2f};prefill_compiles="
         f"{rt_u.prefill_traces}"),
        ("serve_chunked_stall_saving", 0.0,
         f"{(stall_u - stall_c) / stall_u:.0%}_of_long_prompt_"
         f"stall_removed"),
    ]


def _prefix_cache_rows() -> list:
    """Radix prefix cache on a repeated-prefix workload (the frequency-
    category shape: templated prompts sharing a long system prefix).

    Acceptance (asserted):
      * identical greedy tokens with the cache on vs off;
      * prefill tokens computed reduced by >= 50% at 75% prefix overlap;
      * exactly 1 decode compile per service preserved;
      * zero reduction when the cache is disabled (no silent behaviour
        change behind the knob).
    """
    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serving.engine import GenerationRequest, ServiceRuntime

    cfg = ModelConfig(name="bench", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=257, dtype="float32",
                      param_dtype="float32")
    params = T.init(jax.random.PRNGKey(0), cfg)
    # frequency category: the plan's prefix_cache knob derives aggressive
    # retention (mf=1 keeps the BS composer semantics)
    plan = ParallelPlan(service="bench",
                        category=TaskCategory(Sensitivity.FREQUENCY, False),
                        bs=4)
    prefix_len, tail_len, n = 96, 32, (4 if _smoke() else 8)
    rng = np.random.default_rng(11)
    prefix = rng.integers(1, cfg.vocab_size, prefix_len).astype(np.int32)
    tails = [rng.integers(1, cfg.vocab_size, tail_len).astype(np.int32)
             for _ in range(n + 1)]

    def _serve(enabled):
        rt = ServiceRuntime(cfg, params, plan, kvcache_impl="paged",
                            max_seq_len=160, block_size=16,
                            prefix_cache=(None if enabled else 0))
        tokens = {}
        # warm request populates the cache, then the repeated-prefix wave
        rt.submit(GenerationRequest(
            rid=0, tokens=np.concatenate([prefix, tails[0]]),
            max_new_tokens=4))
        tokens.update({r.rid: tuple(r.tokens) for r in rt.drain()})
        for i in range(1, n + 1):
            rt.submit(GenerationRequest(
                rid=i, tokens=np.concatenate([prefix, tails[i]]),
                max_new_tokens=4))
        tokens.update({r.rid: tuple(r.tokens) for r in rt.drain()})
        return rt, tokens

    (rt_on, toks_on), us_on = timed(_serve, True)
    (rt_off, toks_off), us_off = timed(_serve, False)
    total = (n + 1) * (prefix_len + tail_len)
    reduction = 1.0 - rt_on.prefill_tokens_computed / total
    # acceptance gates
    assert toks_on == toks_off          # byte-identical greedy tokens
    assert reduction >= 0.5, (rt_on.prefill_tokens_computed, total)
    assert rt_on.decode_traces <= 1 and rt_off.decode_traces <= 1
    assert rt_off.prefill_tokens_computed == total  # disabled: no reuse
    assert rt_on.prefix_hits >= n       # every wave member hit
    return [
        ("serve_prefix_cache", us_on,
         f"prefill_reduction={reduction:.0%};hits={rt_on.prefix_hits};"
         f"hit_tokens={rt_on.prefix_hit_tokens};"
         f"cow_blocks={rt_on.prefix_cow_copies};"
         f"lru_evictions={rt_on.prefix_evictions};"
         f"decode_compiles={rt_on.decode_traces}"),
        ("serve_prefix_cache_off", us_off,
         f"prefill_tokens={rt_off.prefill_tokens_computed};"
         f"decode_compiles={rt_off.decode_traces}"),
        ("serve_prefix_token_saving", 0.0,
         f"{total - rt_on.prefill_tokens_computed}/{total}"
         f"_prompt_tokens_not_recomputed"),
    ]


def _decode_telemetry_rows() -> list:
    """Zero-gather paged decode vs the dense-gather oracle, with a
    machine-readable ``BENCH_decode.json`` so future PRs have a perf
    trajectory to regress against: per-step decode latency, estimated
    bytes/token from the compiled step's ``cost_analysis()``, compile
    counts and prefill-token counts per variant.

    Acceptance (asserted):
      * identical greedy tokens native vs dense-gather oracle;
      * exactly 1 decode compile per variant (int8 included);
      * the paged-native step's cost_analysis bytes accessed are LOWER
        than the oracle's (no dense KV materialization on the hot path);
      * the int8-quantized native step accesses >= 40% fewer decode
        bytes/token than the native-precision step while matching its
        greedy tokens within tolerance (the native-precision path itself
        stays byte-for-byte identical to the oracle).

    ``BENCH_decode.json`` accumulates one dated entry per run instead of
    overwriting, so the perf trajectory persists across PRs.
    """
    import dataclasses
    import time

    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serving.engine import GenerationRequest, ServiceRuntime

    # a slot budget large enough that the KV pool (the term the gather
    # path round-trips per token) dominates the toy model's weights
    cfg = ModelConfig(name="bench", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=32,
                      d_ff=128, vocab_size=257, dtype="float32",
                      param_dtype="float32")
    params = T.init(jax.random.PRNGKey(0), cfg)
    plan = ParallelPlan(service="bench",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=4)
    n_new = 8 if _smoke() else 24
    max_seq = 256 if _smoke() else 512

    def _measure(native, kv_dtype="bf16"):
        rt = ServiceRuntime(cfg, params,
                            dataclasses.replace(plan, kv_dtype=kv_dtype),
                            kvcache_impl="paged",
                            max_seq_len=max_seq, block_size=32,
                            paged_native=native)
        rng = np.random.default_rng(5)
        tokens = {}
        for i in range(4):
            rt.submit(GenerationRequest(
                rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                           6 + 4 * i).astype(np.int32),
                max_new_tokens=n_new))
        rt.step(); rt.step(); rt.step()     # admit + prefill + warm compile
        agg = StepStatsAggregator().drain(rt)
        lat = [dt for dt, st in agg.timed_steps if st.decode_steps]
        tokens.update({r.rid: tuple(r.tokens) for r in agg.results})
        cost = rt.decode_cost_analysis()
        bytes_accessed = float(cost.get("bytes accessed", 0.0))
        return {
            "decode_bytes_accessed": bytes_accessed,
            "decode_bytes_per_token": bytes_accessed / rt.groups[0]
            .arena.capacity,
            "decode_step_latency_s": {
                "mean": float(np.mean(lat)), "p50": float(np.median(lat)),
                "max": float(np.max(lat)), "steps": len(lat)},
            "decode_compiles": rt.decode_traces,
            "prefill_compiles": rt.prefill_traces,
            "decode_steps": rt.decode_steps,
            "prefill_tokens_computed": rt.prefill_tokens_computed,
            "admission_copy_bytes": rt.admission_copy_bytes,
            "chunk_write_bytes": rt.chunk_write_bytes,
        }, tokens, rt

    native, toks_n, rt_n = _measure(True)
    gather, toks_g, rt_g = _measure(False)
    quant, toks_q, rt_q = _measure(True, kv_dtype="int8")
    # acceptance gates
    assert toks_n == toks_g                       # bit-identical tokens
    assert rt_n.decode_traces <= 1 and rt_g.decode_traces <= 1
    assert rt_q.decode_traces <= 1, rt_q.decode_traces
    assert native["decode_bytes_accessed"] < gather["decode_bytes_accessed"]
    reduction = 1.0 - (native["decode_bytes_accessed"]
                       / gather["decode_bytes_accessed"])
    # int8 pools: >= 40% fewer decode bytes/token than native precision,
    # with tolerance-matching greedy tokens (quantization may flip a near-
    # tie; the overwhelming majority of positions must agree)
    q_reduction = 1.0 - (quant["decode_bytes_per_token"]
                         / native["decode_bytes_per_token"])
    assert q_reduction >= 0.40, (quant["decode_bytes_per_token"],
                                 native["decode_bytes_per_token"])
    assert toks_q.keys() == toks_n.keys()
    positions = sum(len(t) for t in toks_n.values())
    agree = sum(a == b for r in toks_n
                for a, b in zip(toks_n[r], toks_q[r]))
    assert agree >= 0.9 * positions, (agree, positions)
    entry = {
        "date": time.strftime("%Y-%m-%d %H:%M:%S"),
        "workload": {"family": cfg.family, "capacity": 4,
                     "max_seq_len": max_seq, "block_size": 32,
                     "max_new_tokens": n_new, "smoke": _smoke()},
        "variants": {"paged_native": native, "dense_gather": gather,
                     "paged_native_int8": quant},
        "decode_bytes_reduction": reduction,
        "int8_bytes_per_token_reduction": q_reduction,
        "int8_token_agreement": agree / max(1, positions),
    }
    # dated append: the json accumulates one entry per run so the perf
    # trajectory survives across PRs (a legacy single-report file becomes
    # the first entry)
    append_dated_entry("BENCH_decode.json", entry)
    return [
        ("serve_decode_native", native["decode_step_latency_s"]["mean"]
         * 1e6,
         f"bytes_accessed={native['decode_bytes_accessed']:.0f};"
         f"decode_compiles={native['decode_compiles']};"
         f"steps={native['decode_steps']}"),
        ("serve_decode_dense_gather",
         gather["decode_step_latency_s"]["mean"] * 1e6,
         f"bytes_accessed={gather['decode_bytes_accessed']:.0f};"
         f"decode_compiles={gather['decode_compiles']}"),
        ("serve_decode_native_int8",
         quant["decode_step_latency_s"]["mean"] * 1e6,
         f"bytes_accessed={quant['decode_bytes_accessed']:.0f};"
         f"decode_compiles={quant['decode_compiles']};"
         f"token_agreement={agree / max(1, positions):.1%}"),
        ("serve_decode_bytes_saving", 0.0,
         f"{reduction:.0%}_of_decode_step_bytes_removed;"
         f"int8_bytes_per_token_saving={q_reduction:.0%};"
         f"json=BENCH_decode.json"),
    ]


def _speculative_rows() -> list:
    """Speculative decoding with a self-draft (draft = target params, so
    greedy agreement is 100%%): accepted tokens per fused verify launch
    must reach ``k+1`` and the emitted tokens must be bit-identical to
    the non-speculative oracle.

    Acceptance (asserted):
      * identical greedy tokens speculative vs plain paged-native;
      * >= 1.5 accepted tokens per verify launch (the issue's floor;
        a self-draft actually sustains ``k+1 = 4``);
      * compile discipline: exactly 1 verify trace, <= 1 decode trace
        and <= 1 draft decode trace per service.

    Appends a dated ``speculative`` entry to ``BENCH_decode.json`` so the
    acceptance-rate trajectory persists across PRs.
    """
    import time

    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.serving.engine import GenerationRequest, ServiceRuntime

    cfg = _toy_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    plan = ParallelPlan(service="bench",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=4)
    n_new = 8 if _smoke() else 24
    k = 3
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab_size, 6 + 4 * i).astype(np.int32)
               for i in range(4)]

    def _run(speculate):
        rt = ServiceRuntime(
            cfg, params, plan, kvcache_impl="paged",
            draft_params=params if speculate else None,
            draft_cfg=cfg if speculate else None,
            speculate=k if speculate else None)
        for i, p in enumerate(prompts):
            rt.submit(GenerationRequest(rid=i, tokens=p.copy(),
                                        max_new_tokens=n_new))
        t0 = time.perf_counter()
        tokens = {r.rid: tuple(r.tokens) for r in rt.drain()}
        return tokens, time.perf_counter() - t0, rt

    toks_plain, s_plain, _ = _run(False)
    toks_spec, s_spec, rt = _run(True)
    # acceptance gates
    assert toks_spec == toks_plain            # bit-identical to the oracle
    assert rt.verify_launches > 0
    per_launch = rt.accepted_tokens / rt.verify_launches
    assert per_launch >= 1.5, (rt.accepted_tokens, rt.verify_launches)
    assert rt.verify_traces == 1, rt.verify_traces
    assert rt.decode_traces <= 1, rt.decode_traces
    assert rt.draft_decode_traces <= 1, rt.draft_decode_traces
    entry = {
        "date": time.strftime("%Y-%m-%d %H:%M:%S"),
        "section": "speculative",
        "workload": {"family": cfg.family, "capacity": 4, "k": k,
                     "max_new_tokens": n_new, "smoke": _smoke(),
                     "draft": "self (100% greedy agreement)"},
        "verify_launches": rt.verify_launches,
        "accepted_tokens": rt.accepted_tokens,
        "accepted_per_launch": per_launch,
        "draft_steps": rt.draft_steps,
        "spec_degraded": rt.spec_degraded,
        "verify_compiles": rt.verify_traces,
        "draft_decode_compiles": rt.draft_decode_traces,
        "drain_s": {"plain": s_plain, "speculative": s_spec},
    }
    append_dated_entry("BENCH_decode.json", entry)
    return [
        ("serve_spec_decode", s_spec * 1e6,
         f"accepted_per_launch={per_launch:.2f};"
         f"verify_launches={rt.verify_launches};"
         f"draft_steps={rt.draft_steps};"
         f"verify_compiles={rt.verify_traces}"),
        ("serve_spec_oracle", s_plain * 1e6,
         "plain_paged_native_same_workload"),
        ("serve_spec_tokens_identical", 0.0,
         f"bit_identical_to_oracle;k={k};json=BENCH_decode.json"),
    ]


def _goodput_overload_rows() -> list:
    """Goodput under bursty ~2x-capacity overload: strictest-deadline-
    first admission with block-table-parking preemption (``admission=
    "sdf"``) vs the FIFO baseline, on the live engine under a logical
    clock (one tick per engine round, so results are machine-independent
    and deterministic).

    The trace fills both slots with deadline-less long decodes, then
    streams urgent short requests whose deadlines are feasible only if
    they are served promptly — FIFO serves them dead behind the stragglers,
    SDF parks a straggler's blocks and serves them on time.

    Acceptance (asserted):
      * SDF goodput (on-time completions) >= 1.3x FIFO on the same trace;
      * every request completed under BOTH policies has bit-identical
        greedy tokens (parking/resume never corrupts a decode);
      * exactly 1 decode compile per service under either policy;
      * zero verdict-less drops: completed + rejected == submitted.

    ``BENCH_goodput.json`` accumulates one dated entry per run, the same
    trajectory pattern as ``BENCH_decode.json``.
    """
    import time

    import jax

    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T
    from repro.serving.engine import GenerationRequest, ServiceRuntime

    cfg = _toy_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    plan = ParallelPlan(service="toy",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=2)
    n_urgent = 4 if _smoke() else 8
    long_new = 24 if _smoke() else 48
    budget = 14.0                     # urgent deadline: submit + budget

    def _trace(policy):
        import dataclasses
        rt = ServiceRuntime(cfg, params,
                            dataclasses.replace(plan, admission=policy))
        rng = np.random.default_rng(7)
        agg, t = StepStatsAggregator(), 0.0
        deadlines = {}                # rid -> deadline (0 = none)

        def drain():
            nonlocal t
            while rt.pending() or rt.in_flight():
                agg.add(rt.step(now=t))
                t += 1.0
                assert t < 5000.0, "engine failed to drain"

        # warmup: two deadline-less shorts teach the controller the
        # caller's round/service clock (a cold controller is FIFO)
        for i in range(2):
            rt.submit(GenerationRequest(
                rid=1000 + i,
                tokens=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
                max_new_tokens=4), now=t)
        drain()
        submitted = 2
        # overload: two deadline-less stragglers take both slots...
        for i in range(2):
            rt.submit(GenerationRequest(
                rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                           6).astype(np.int32),
                max_new_tokens=long_new), now=t)
            submitted += 1
        for _ in range(2):
            agg.add(rt.step(now=t))
            t += 1.0
        # ...then urgent shorts stream in at ~2x the slot turnover rate
        for i in range(n_urgent):
            deadlines[100 + i] = t + budget
            rt.submit(GenerationRequest(
                rid=100 + i,
                tokens=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
                max_new_tokens=4, deadline_s=t + budget), now=t)
            submitted += 1
            for _ in range(3):
                agg.add(rt.step(now=t))
                t += 1.0
        drain()
        ontime = sum(1 for r in agg.results
                     if not deadlines.get(r.rid)
                     or r.finished_s <= deadlines[r.rid])
        return rt, agg.results, agg.rejected, ontime, submitted

    def _measure(policy):
        (rt, results, rejects, ontime, submitted), us = timed(_trace, policy)
        # zero verdict-less drops: every request served or verdicted
        assert len(results) + len(rejects) == submitted, policy
        assert rt.decode_traces == 1, (policy, rt.decode_traces)
        ctrl = rt.admission
        return {
            "goodput_ontime": ontime,
            "completed": len(results),
            "rejected": len(rejects),
            "submitted": submitted,
            "preemptions": ctrl.preemptions,
            "resumes": ctrl.resumes,
            "verdicts": dict(ctrl.verdicts),
            "arena_parks": sum(g.arena.parks for g in rt.groups.values()
                               if g.arena is not None),
            "wall_us": us,
        }, {r.rid: tuple(int(x) for x in r.tokens) for r in results}

    fifo, toks_f = _measure("fifo")
    sdf, toks_s = _measure("sdf")
    # parked-then-resumed decodes stay bit-identical to never-parked ones
    both = set(toks_f) & set(toks_s)
    assert both and all(toks_f[r] == toks_s[r] for r in both), \
        sorted(r for r in both if toks_f[r] != toks_s[r])
    ratio = sdf["goodput_ontime"] / max(1, fifo["goodput_ontime"])
    assert ratio >= 1.3, (sdf["goodput_ontime"], fifo["goodput_ontime"])
    assert sdf["preemptions"] >= 1 and \
        sdf["resumes"] == sdf["preemptions"] == sdf["arena_parks"]
    entry = {
        "date": time.strftime("%Y-%m-%d %H:%M:%S"),
        "workload": {"slots": 2, "urgent": n_urgent, "long_new": long_new,
                     "deadline_budget_ticks": budget, "smoke": _smoke()},
        "policies": {"fifo": fifo, "sdf": sdf},
        "goodput_ratio": ratio,
        "bit_identical_rids": len(both),
    }
    append_dated_entry("BENCH_goodput.json", entry)
    return [
        ("serve_goodput_fifo", fifo["wall_us"],
         f"ontime={fifo['goodput_ontime']}/{fifo['submitted']};"
         f"completed={fifo['completed']};rejected={fifo['rejected']}"),
        ("serve_goodput_sdf", sdf["wall_us"],
         f"ontime={sdf['goodput_ontime']}/{sdf['submitted']};"
         f"preemptions={sdf['preemptions']};resumes={sdf['resumes']};"
         f"verdicts={sdf['verdicts']}"),
        ("serve_goodput_ratio", 0.0,
         f"sdf_over_fifo={ratio:.2f}x;bit_identical_rids={len(both)};"
         f"json=BENCH_goodput.json"),
    ]


def _chaos_rows() -> list:
    """Fault-tolerant serving under a deterministic crash (§5.3.3): a
    3-server toy cluster serves a bursty deadline-carrying trace while
    server 0 is crashed mid-burst and restarted a few rounds later.  The
    supervisor evacuates the corpse's queued/in-flight/parked requests
    and resubmits them to survivors with timeout/backoff; the restarted
    server rejoins cold via ``repair()`` + re-publish.

    Acceptance (asserted):
      * on-time goodput under the crash >= 0.6x the failure-free run;
      * zero silently lost requests: served + verdicted == submitted;
      * every retried request's greedy tokens are bit-identical to the
        failure-free oracle's (counter-stream sampling replays exactly);
      * decode compiles exactly once per surviving service runtime;
      * the crashed server is repaired by the end (cluster healed).

    Appends a dated ``chaos`` entry to ``BENCH_goodput.json``.
    """
    import time

    import jax

    from repro.core import EdgeCloudControlPlane, ServerSpec, ServiceSpec
    from repro.core.faults import FaultEvent, FaultInjector, FaultSpec
    from repro.models import transformer as T
    from repro.serving.engine import (EparaServingEngine, GenerationRequest,
                                      ServiceRuntime)
    from repro.serving.failover import ClusterSupervisor, RetryPolicy

    cfg = _toy_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    n_requests = 9 if _smoke() else 18
    budget = 40.0                    # deadline: submit + budget ticks
    spec = FaultSpec(events=(
        FaultEvent(at_s=2.0, kind="crash", sid=0),
        FaultEvent(at_s=8.0, kind="restart", sid=0)))

    def _cluster():
        specs = {"chat": ServiceSpec("chat", flops_per_request=1e10,
                                     weights_bytes=2e8, vram_bytes=5e8,
                                     slo_latency_s=100.0)}
        servers = [ServerSpec(sid=i, num_gpus=2) for i in range(3)]
        cp = EdgeCloudControlPlane(servers, specs)
        cp.run_placement({("chat", i): 10.0 for i in range(3)})
        engines = {s.sid: EparaServingEngine() for s in servers}
        for sid in engines:
            engines[sid].deploy("chat", ServiceRuntime(cfg, params,
                                                       cp.plans["chat"]))
        cp.publish_all(0.0)
        for _ in range(3):
            cp.sync_step(0.0)
        return cp, engines

    def _serve(chaos):
        cp, engines = _cluster()
        injector = FaultInjector(spec) if chaos else None
        sup = ClusterSupervisor(cp, engines,
                                retry=RetryPolicy(base_timeout_s=4.0),
                                injector=injector)
        rng = np.random.default_rng(11)
        for i in range(n_requests):
            sup.submit("chat", GenerationRequest(
                rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                           6).astype(np.int32),
                max_new_tokens=4, deadline_s=budget, stream=i),
                at_server=i % 3, now=0.0)
        report = sup.run_until_idle()
        assert report.accounted == n_requests, \
            ("silently lost requests", chaos, report.accounted)
        ontime = sum(1 for r in report.results
                     if r.sample == 0 and r.finished_s <= budget)
        for sid, eng in engines.items():
            for rt in eng.runtimes.values():
                assert rt.decode_traces <= 1, (sid, rt.decode_traces)
                if chaos and sid != 0:
                    assert rt.decode_traces == 1, ("survivor idle", sid)
        if chaos:
            assert not sup.down, "crashed server was never repaired"
            assert report.evacuated > 0 and report.failovers > 0
        toks = {r.rid: tuple(int(x) for x in r.tokens)
                for r in report.results if r.sample == 0}
        return report, ontime, toks

    (base, ontime_base, toks_base), us_base = timed(_serve, False)
    (chaos, ontime_chaos, toks_chaos), us_chaos = timed(_serve, True)
    ratio = ontime_chaos / max(1, ontime_base)
    assert ratio >= 0.6, (ontime_chaos, ontime_base)
    both = set(toks_base) & set(toks_chaos)
    bad = sorted(r for r in both if toks_base[r] != toks_chaos[r])
    assert both and not bad, bad
    entry = {
        "date": time.strftime("%Y-%m-%d %H:%M:%S"),
        "section": "chaos",
        "workload": {"servers": 3, "requests": n_requests,
                     "deadline_budget_ticks": budget, "smoke": _smoke(),
                     "fault_spec": spec.to_json()},
        "failure_free": {"ontime": ontime_base, "rounds": base.rounds,
                         "wall_us": us_base},
        "chaos": {"ontime": ontime_chaos, "rounds": chaos.rounds,
                  "evacuated": chaos.evacuated,
                  "failovers": chaos.failovers,
                  "duplicates": chaos.duplicates,
                  "verdicted": len(chaos.rejects),
                  "wall_us": us_chaos},
        "goodput_ratio": ratio,
        "bit_identical_rids": len(both),
    }
    append_dated_entry("BENCH_goodput.json", entry)
    return [
        ("serve_chaos_free", us_base,
         f"ontime={ontime_base}/{n_requests};rounds={base.rounds}"),
        ("serve_chaos_crash", us_chaos,
         f"ontime={ontime_chaos}/{n_requests};"
         f"evacuated={chaos.evacuated};failovers={chaos.failovers};"
         f"verdicted={len(chaos.rejects)}"),
        ("serve_chaos_ratio", 0.0,
         f"chaos_over_free={ratio:.2f}x;bit_identical_rids={len(both)};"
         f"json=BENCH_goodput.json"),
    ]


def _simulator_rows() -> list:
    import dataclasses

    from repro.simulator.engine import run_comparison

    from .common import testbed_scenario

    horizon = 10.0 if _smoke() else 40.0
    load = 10.0 if _smoke() else 30.0
    services, servers, events, cfg = testbed_scenario(horizon=horizon,
                                                      load=load, seed=3)
    rows = []
    goodput = {}
    for mode in ("paged", "continuous", "sync"):
        c = dataclasses.replace(cfg, serving_mode=mode,
                                admission_copy_s=0.01)
        out, us = timed(run_comparison, servers, services, events,
                        ["EPARA"], c)
        r = out["EPARA"]
        goodput[mode] = r.goodput
        rows.append((f"sim_{mode}", us,
                     f"goodput={r.goodput:.2f};fulfillment="
                     f"{r.fulfillment:.3f}"))
    # paged removes the per-admission copy stall, so its goodput must not
    # trail continuous (deterministic since SSSP's equal-gain tiebreak is
    # value-based; see core/placement.py)
    assert goodput["paged"] >= goodput["continuous"], goodput
    return rows


def run() -> list:
    """REPRO_BENCH_SECTION selects sections (comma list of
    live|chunked|prefix|decode|spec|goodput|chaos|sim); unset runs them
    all.
    ``make bench-paged`` pins ``live,sim``, ``make bench-chunked`` pins
    ``chunked``, ``make bench-prefix`` pins ``prefix``, ``make
    bench-decode`` pins ``decode`` (which also writes
    ``BENCH_decode.json``), ``make bench-spec`` pins ``spec`` (appending
    a speculative entry to the same json), ``make bench-goodput`` pins
    ``goodput`` (``BENCH_goodput.json``) and ``make bench-chaos`` pins
    ``chaos`` (appending a crash-recovery entry to the same json) so the
    targets do not re-run each other's workloads."""
    sections = [s for s in os.environ.get("REPRO_BENCH_SECTION",
                                          "").split(",") if s]
    rows: list = []
    if not sections or "live" in sections:
        rows.extend(_live_engine_rows())
    if not sections or "chunked" in sections:
        rows.extend(_chunked_prefill_rows())
    if not sections or "prefix" in sections:
        rows.extend(_prefix_cache_rows())
    if not sections or "decode" in sections:
        rows.extend(_decode_telemetry_rows())
    if not sections or "spec" in sections:
        rows.extend(_speculative_rows())
    if not sections or "goodput" in sections:
        rows.extend(_goodput_overload_rows())
    if not sections or "chaos" in sections:
        rows.extend(_chaos_rows())
    if not sections or "sim" in sections:
        rows.extend(_simulator_rows())
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
