"""Shared benchmark scaffolding: scenario builders + the CSV row format
(``name,us_per_call,derived``) used by every module."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

from repro.core.categories import EDGE_P100, ServerSpec
from repro.simulator.engine import SimConfig, Simulation, run_comparison
from repro.simulator.workload import (WorkloadConfig,
                                      derive_prefix_hit_rates,
                                      generate_requests, table1_services)

Row = Tuple[str, float, str]


def testbed_scenario(*, servers=6, load=30.0, horizon=40.0, seed=1,
                     freq_share=0.5, skew=0.7, prompt_tokens=0,
                     template_tokens=0):
    """The paper's testbed shape: six P100 servers, Table-1 services,
    Azure-like bursty arrivals at ~saturating load.  ``skew`` routes that
    fraction of arrivals to the first third of servers — the paper's
    'abrupt or uneven requests in edge' (this is precisely where
    state-aware offloading beats blind round-robin).

    Nonzero ``prompt_tokens``/``template_tokens`` turn on templated
    prompts for latency arrivals and price prefix reuse truthfully: the
    returned ``SimConfig`` carries PER-SERVICE hit rates derived from the
    trace's actual template-repeat structure
    (``derive_prefix_hit_rates``) instead of a hand-tuned scalar."""
    import numpy as np
    services = table1_services()
    srv = [ServerSpec(sid=i, num_gpus=1, gpu=EDGE_P100)
           for i in range(servers)]
    wl = WorkloadConfig(horizon_s=horizon, load_scale=load, seed=seed,
                        freq_share=freq_share, prompt_tokens=prompt_tokens,
                        template_tokens=template_tokens)
    events = generate_requests(services, servers, wl)
    if skew:
        rng = np.random.default_rng(seed + 99)
        hot = max(1, servers // 3)
        skewed = []
        for t, sid, r in events:
            if rng.random() < skew:
                sid = int(rng.integers(0, hot))
            skewed.append((t, sid, r))
        events = skewed
    cfg = SimConfig(horizon_s=horizon)
    if prompt_tokens > 0:
        # derived AFTER skew: a template repeat only hits if the same
        # server actually sees it, so re-routing lowers the honest rate
        cfg = SimConfig(horizon_s=horizon, prefill_token_s=2e-4,
                        prefix_hit_rates=derive_prefix_hit_rates(
                            events, services, wl))
    return services, srv, events, cfg


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)
