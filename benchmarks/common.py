"""Shared benchmark scaffolding: scenario builders, the CSV row format
(``name,us_per_call,derived``) used by every module, the ``StepStats``
aggregator every live-engine section drives its step loop through, and
the dated-append helper for the ``BENCH_*.json`` trajectory files."""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Tuple

from repro.core.categories import EDGE_P100, ServerSpec
from repro.obs.metrics import step_stat_sums
from repro.simulator.engine import SimConfig, Simulation, run_comparison
from repro.simulator.workload import (WorkloadConfig,
                                      derive_prefix_hit_rates,
                                      generate_requests, table1_services)

Row = Tuple[str, float, str]


class StepStatsAggregator:
    """Accumulate a serving run's per-step telemetry in one place.

    Numeric delta fields fold through ``repro.obs.metrics.
    step_stat_sums`` — the SAME fold the metrics registry's
    ``observe_step`` runs — so a benchmark's summed counters and an
    exported metrics file can never disagree about what a run did.
    Results and admission rejects collect in submission order, and each
    step's wall time is kept alongside its ``StepStats`` so stall
    analyses (e.g. the chunked-prefill head-of-line bound) can filter
    steps by what they did."""

    def __init__(self):
        self.sums: Dict[str, float] = {}
        self.results: List[Any] = []
        self.rejected: List[Any] = []
        self.timed_steps: List[Tuple[float, Any]] = []   # (wall_s, stats)
        self.steps = 0

    def add(self, stats, wall_s: float = 0.0):
        """Fold one ``StepStats`` (with its measured wall time) in."""
        step_stat_sums(stats, into=self.sums)
        self.results.extend(stats.results)
        self.rejected.extend(stats.rejected)
        self.timed_steps.append((wall_s, stats))
        self.steps += 1
        return stats

    def drain(self, rt, **step_kw) -> "StepStatsAggregator":
        """Step ``rt`` until queue and slots are empty, timing each
        scheduling round."""
        while rt.pending() or rt.in_flight():
            t0 = time.perf_counter()
            stats = rt.step(**step_kw)
            self.add(stats, time.perf_counter() - t0)
        return self

    def tokens(self) -> Dict[int, tuple]:
        """Finished requests' emitted tokens keyed by rid."""
        return {r.rid: tuple(int(x) for x in r.tokens)
                for r in self.results}


def append_dated_entry(path: str, entry: Dict[str, Any]) -> Dict[str, Any]:
    """Append one dated entry to a ``BENCH_*.json`` trajectory file:
    the file holds ``{"entries": [...]}`` accumulated across PRs; a
    legacy single-report dict migrates to the first entry; a missing or
    corrupt file starts the history fresh.  Returns what was written."""
    history: Dict[str, Any] = {"entries": []}
    try:
        with open(path) as f:
            prev = json.load(f)
        if isinstance(prev, dict) and isinstance(prev.get("entries"), list):
            history = prev
        elif isinstance(prev, dict) and prev:
            history["entries"].append(prev)
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    history["entries"].append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
    return history


def testbed_scenario(*, servers=6, load=30.0, horizon=40.0, seed=1,
                     freq_share=0.5, skew=0.7, prompt_tokens=0,
                     template_tokens=0):
    """The paper's testbed shape: six P100 servers, Table-1 services,
    Azure-like bursty arrivals at ~saturating load.  ``skew`` routes that
    fraction of arrivals to the first third of servers — the paper's
    'abrupt or uneven requests in edge' (this is precisely where
    state-aware offloading beats blind round-robin).

    Nonzero ``prompt_tokens``/``template_tokens`` turn on templated
    prompts for latency arrivals and price prefix reuse truthfully: the
    returned ``SimConfig`` carries PER-SERVICE hit rates derived from the
    trace's actual template-repeat structure
    (``derive_prefix_hit_rates``) instead of a hand-tuned scalar."""
    import numpy as np
    services = table1_services()
    srv = [ServerSpec(sid=i, num_gpus=1, gpu=EDGE_P100)
           for i in range(servers)]
    wl = WorkloadConfig(horizon_s=horizon, load_scale=load, seed=seed,
                        freq_share=freq_share, prompt_tokens=prompt_tokens,
                        template_tokens=template_tokens)
    events = generate_requests(services, servers, wl)
    if skew:
        rng = np.random.default_rng(seed + 99)
        hot = max(1, servers // 3)
        skewed = []
        for t, sid, r in events:
            if rng.random() < skew:
                sid = int(rng.integers(0, hot))
            skewed.append((t, sid, r))
        events = skewed
    cfg = SimConfig(horizon_s=horizon)
    if prompt_tokens > 0:
        # derived AFTER skew: a template repeat only hits if the same
        # server actually sees it, so re-routing lowers the honest rate
        cfg = SimConfig(horizon_s=horizon, prefill_token_s=2e-4,
                        prefix_hit_rates=derive_prefix_hit_rates(
                            events, services, wl))
    return services, srv, events, cfg


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)
