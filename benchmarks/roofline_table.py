"""Deliverable (g): the roofline table — reads results/dryrun JSONs (written
by ``python -m repro.launch.dryrun --all --both-meshes``) and reports the
three terms + dominant bottleneck per (arch x shape x mesh).  If the sweep
has not been run, emits a pointer row instead of failing."""
from __future__ import annotations

import glob
import json
import os

OUT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run() -> list:
    rows = []
    recs = load_records()
    if not recs:
        return [("roofline/missing", 0.0,
                 "run:python -m repro.launch.dryrun --all --both-meshes")]
    fits = sum(1 for r in recs if r.get("fits_hbm"))
    rows.append(("roofline/combos_compiled", 0.0, f"{len(recs)}"))
    rows.append(("roofline/fit_16gb", 0.0, f"{fits}_of_{len(recs)}"))
    for r in recs:
        if r["mesh"] != "pod256":
            continue   # the roofline table is single-pod (brief)
        name = f"roofline/{r['arch']}/{r['shape']}"
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((name, r["compile_s"] * 1e6,
                     f"dom={r['dominant']};c={r['compute_s']:.3g}s"
                     f";m={r['memory_s']:.3g}s;x={r['collective_s']:.3g}s"
                     f";useful={r['useful_flops_ratio']:.2f}"))
    return rows
