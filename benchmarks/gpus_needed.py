"""Fig. 15 — GPUs needed to satisfy a fixed workload within SLOs; the paper
reports EPARA needs 1.5-2.6x fewer.  We sweep GPU counts and report the
smallest count at which each scheduler reaches >=95% fulfillment."""
from __future__ import annotations

from repro.core.categories import EDGE_P100, ServerSpec
from repro.simulator.engine import SimConfig, run_comparison
from repro.simulator.workload import (WorkloadConfig, generate_requests,
                                      table1_services)

from .common import timed

TARGET = 0.93
BASELINES = ["EPARA", "InterEdge", "Galaxy", "SERV-P"]


def _min_gpus(name, services, events, n_servers, cfg):
    from repro.simulator.baselines import make_scheduler
    from repro.simulator.engine import Simulation
    for gpus in (1, 2, 3, 4, 6, 8, 12, 16):
        servers = [ServerSpec(sid=i, num_gpus=gpus, gpu=EDGE_P100)
                   for i in range(n_servers)]
        sched = make_scheduler(name, services, EDGE_P100)
        r = Simulation(servers, services, sched, events, cfg).run()
        if r.fulfillment >= TARGET:
            return gpus * n_servers
    return 16 * n_servers


def run() -> list:
    rows = []
    services = table1_services()
    n = 4
    wl = WorkloadConfig(horizon_s=25.0, load_scale=25.0, seed=5)
    events = generate_requests(services, n, wl)
    cfg = SimConfig(horizon_s=25.0)
    needs = {}
    import time
    t0 = time.perf_counter()
    for name in BASELINES:
        needs[name] = _min_gpus(name, services, events, n, cfg)
    us = (time.perf_counter() - t0) * 1e6 / len(BASELINES)
    for name in BASELINES[1:]:
        rows.append((f"gpus_needed/{name}_over_EPARA", us,
                     f"{needs[name] / needs['EPARA']:.2f}x"))
    rows.append(("gpus_needed/EPARA_abs", us, f"{needs['EPARA']}gpus"))
    return rows
