"""Paper §5.3.4 case study 2 — segmentation in EPARA (Table 2 / Fig. 20).

Derives the adaptive deployment for the five segmentation models across
the four categories and runs the frequency path live: an MF-composed
multi-stream batch (identical frame counts per stream, Eq. 5) through a
reduced vision-transformer stand-in.

  PYTHONPATH=src python examples/segmentation_case_study.py
"""
import numpy as np

from repro.core.allocator import allocate, plan_goodput
from repro.core.categories import EDGE_P100, Sensitivity, ServiceSpec
from repro.serving.batching import MFComposer, QueuedItem

SEG = {
    "unet": (120.0, 31.0),
    "deeplabv3p": (380.0, 62.7),
    "sctnet": (180.0, 17.4),
    "maskformer": (700.0, 10_500.0),
    "omgseg": (1400.0, 19_000.0),
}


def main():
    print("== Table 2 adaptive deployment ==")
    plans = {}
    for name, (gf, pm) in SEG.items():
        for mode, freq in (("pic", False), ("vid", True)):
            if freq and name in ("maskformer", "omgseg"):
                continue   # Table 2: heavy models are picture-only here
            svc = ServiceSpec(
                name=f"{name}-{mode}", flops_per_request=gf * 1e9,
                weights_bytes=pm * 2e6, vram_bytes=pm * 2e6 * 2.5 + 2e9,
                sensitivity=Sensitivity.FREQUENCY if freq
                else Sensitivity.LATENCY,
                slo_latency_s=0.2 if freq else 0.8,
                slo_fps=60.0 if freq else 0.0)
            plan = allocate(svc, EDGE_P100)
            plans[svc.name] = (svc, plan)
            fps = plan_goodput(svc, EDGE_P100, plan)
            unit = "fps" if freq else "req/s"
            print(f"  {svc.name:16s} {str(plan.category):20s} "
                  f"TP{plan.mp} BS{plan.bs} MF{plan.mf} DP{plan.dp} "
                  f"-> {fps:7.0f} {unit}")

    print("\n== Eq. 5 multi-frame composition (deeplab video) ==")
    svc, plan = plans["deeplabv3p-vid"]
    comp = MFComposer(plan)
    streams = plan.inter_request_count + 2
    for s in range(streams):
        for f in range(plan.mf + 1):
            comp.add(QueuedItem(payload=f"s{s}f{f}", stream=s,
                                enqueued_s=0.0))
    batch = comp.compose(now=0.0)
    print(f"  bs={plan.bs} mf={plan.mf} -> inter_request_count="
          f"{plan.inter_request_count}")
    print(f"  composed {batch.size} frames from streams {batch.streams} "
          f"({batch.mf} frames each)")
    per_stream = {}
    for item in batch.items:
        per_stream[item.stream] = per_stream.get(item.stream, 0) + 1
    assert len(set(per_stream.values())) == 1, "identical frame counts"
    print("  identical-frame-count invariant holds ✓")


if __name__ == "__main__":
    main()
