"""End-to-end serving driver (the paper-kind driver, deliverable b):
deploy reduced variants of THREE assigned architectures (dense + SSM +
VLM) across a simulated edge cloud and serve a batched request stream
through the full EPARA control plane — allocator, SSSP placement, ring
sync, and per-request handler decisions, with MF batch composition for
the frequency service and sticky DP routing for the stateful SSM.

  PYTHONPATH=src python examples/serve_cluster.py [--requests 24]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import (EdgeCloudControlPlane, Outcome, Request, ServerSpec,
                        ServiceSpec, Sensitivity)
from repro.core.faults import FaultEvent, FaultInjector, FaultSpec
from repro.models.registry import model_api
from repro.serving.engine import (EparaServingEngine, GenerationRequest,
                                  ServiceRuntime)
from repro.serving.failover import ClusterSupervisor, RetryPolicy

ARCHS = ["codeqwen1.5-7b", "mamba2-2.7b", "paligemma-3b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--servers", type=int, default=3)
    ap.add_argument("--chaos", action="store_true",
                    help="crash one server mid-burst (then restart it): "
                         "its queued/in-flight/parked requests evacuate "
                         "to survivors and every rid must still end "
                         "served-or-verdicted")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome-trace JSON of request lifecycles "
                         "and engine phases (Perfetto-loadable)")
    ap.add_argument("--metrics-out", default="",
                    help="write Prometheus text exposition (or a JSONL "
                         "snapshot when the path ends in .jsonl)")
    args = ap.parse_args()

    tracer = metrics = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()

    specs, cfgs = {}, {}
    for a in ARCHS:
        full = get_config(a)
        freq = full.epara_sensitivity == "frequency"
        specs[a] = ServiceSpec(
            name=a, flops_per_request=2 * full.active_param_count() * 64,
            weights_bytes=full.param_count() * 2.0,
            vram_bytes=full.param_count() * 3.0,
            sensitivity=Sensitivity.FREQUENCY if freq
            else Sensitivity.LATENCY,
            slo_latency_s=2.0, slo_fps=20.0 if freq else 0.0,
            stateful=full.family in ("ssm", "hybrid"))
        cfgs[a] = reduced(full)

    servers = [ServerSpec(sid=i, num_gpus=4) for i in range(args.servers)]
    cp = EdgeCloudControlPlane(servers, specs)
    placements = cp.run_placement(
        {(a, s.sid): 5.0 for a in ARCHS for s in servers})
    print("plans:")
    for a, plan in cp.plans.items():
        print(f"  {a:18s} {plan.category} mp={plan.mp} bs={plan.bs} "
              f"mt={plan.mt} mf={plan.mf} dp={plan.dp} "
              f"sticky={plan.sticky}")
    print("placements:", placements)

    engines = {s.sid: EparaServingEngine() for s in servers}
    rng = np.random.default_rng(0)
    for svc, sid in placements:
        if sid < 0:
            continue
        cfg = cfgs[svc]
        params = model_api(cfg).init(
            jax.random.PRNGKey(abs(hash(svc)) % 2**31), cfg)
        engines[sid].deploy(svc, ServiceRuntime(cfg, params, cp.plans[svc],
                                                tracer=tracer,
                                                metrics=metrics))

    cp.publish_all(0.0)
    for _ in range(args.servers):
        cp.sync_step(0.0)

    t0 = time.time()
    injector = None
    if args.chaos:
        # deterministic mid-burst crash of one service host, restarted a
        # few rounds later (rejoins via repair + re-publish); the first
        # logical round is t=1.0, so at_s=2.0 lands while requests are
        # still queued or decoding
        victim = next(sid for sid, e in engines.items() if e.runtimes)
        injector = FaultInjector(FaultSpec(events=(
            FaultEvent(at_s=2.0, kind="crash", sid=victim),
            FaultEvent(at_s=6.0, kind="restart", sid=victim))))
        print(f"chaos: crash server {victim} at t=2, restart at t=6")
    supervisor = ClusterSupervisor(cp, engines,
                                   retry=RetryPolicy(base_timeout_s=4.0),
                                   injector=injector, metrics=metrics,
                                   tracer=tracer)
    for i in range(args.requests):
        svc = ARCHS[i % len(ARCHS)]
        cfg = cfgs[svc]
        at = int(rng.integers(0, args.servers))
        extras = None
        if cfg.family == "vlm":
            extras = {"embeddings": np.zeros((cfg.prefix_len, cfg.d_model),
                                             np.float32)}
        supervisor.submit(svc, GenerationRequest(
            rid=i, tokens=rng.integers(0, cfg.vocab_size, 8,
                                       dtype=np.int64).astype(np.int32),
            max_new_tokens=6, stream=i % 4, extras=extras),
            at_server=at, now=0.0)
    # the supervisor steps every runtime until each rid is served or
    # verdicted, feeding queue-time estimates back to the handler state
    # and recovering from any injected faults along the way
    report = supervisor.run_until_idle()
    results = report.results
    outcomes = report.outcomes
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    steps = sum(rt.decode_steps for eng in engines.values()
                for rt in eng.runtimes.values())
    traces = sum(rt.decode_traces for eng in engines.values()
                 for rt in eng.runtimes.values())
    copies = sum(rt.whole_cache_copies for eng in engines.values()
                 for rt in eng.runtimes.values())
    chunks = sum(rt.prefill_chunk_calls for eng in engines.values()
                 for rt in eng.runtimes.values())
    deployed = sum(len(eng.runtimes) for eng in engines.values())
    print(f"\nserved {len(results)}/{args.requests} requests "
          f"({toks} tokens, {steps} fused decode steps, {chunks} prefill "
          f"chunks) in {dt:.1f}s — handler outcomes: {outcomes}")
    print(f"paged arena: {traces} decode compiles across {deployed} "
          f"deployed runtimes, {copies} whole-cache admission copies")
    if args.chaos:
        print(f"chaos: {report.evacuated} evacuated, {report.failovers} "
              f"failovers, {report.duplicates} duplicates deduplicated, "
              f"{len(report.rejects)} verdicted, "
              f"accounted {report.accounted}/{args.requests}")
    if tracer is not None:
        tracer.export(args.trace_out)
        print(f"trace: {tracer.emitted} events -> {args.trace_out}")
    if metrics is not None:
        if args.metrics_out.endswith(".jsonl"):
            metrics.append_jsonl(args.metrics_out)
        else:
            metrics.write_prometheus(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    # served-or-verdicted: every rid is accounted for even when a server
    # crashed mid-burst (chaos mode); without faults nothing may be
    # verdicted at all
    assert report.accounted == args.requests, \
        (report.accounted, args.requests)
    if not args.chaos:
        assert len({r.rid for r in results}) == args.requests
    assert copies == 0          # arena admissions never copy the live batch


if __name__ == "__main__":
    main()
