"""Paper §4.3 case study — "LLMs from chats to robots".

Reproduces the paper's categorical deployment: the same LLM is
latency-sensitive as a chat service and frequency-sensitive as an HCI
(virtual-assistant / robot) service; EPARA's adaptive deployment (§4.1)
derives different operator mixes for each, then a reduced model serves
both patterns live — the HCI path uses DP round-robin across replica
groups with instant switching to the latest decode output.

  PYTHONPATH=src python examples/llm_case_study.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.allocator import DPGroupRouter, allocate
from repro.core.categories import EDGE_P100, Sensitivity, ServiceSpec
from repro.models.registry import model_api
from repro.serving.engine import GenerationRequest, ServiceRuntime

MODELS = {  # name: (params B, active B)
    "qwen2.5-1.5b": (1.5, 1.5),
    "llama3-8b": (8.0, 8.0),
    "deepseekv2-16b": (16.0, 2.4),
    "qwen2.5-32b": (32.0, 32.0),
}


def main():
    print("== §4.3 adaptive deployment (paper Fig. 8 analogue) ==")
    for name, (size, active) in MODELS.items():
        for mode, freq in (("chat", False), ("hci", True)):
            toks = 16 if freq else 256
            svc = ServiceSpec(
                name=f"{name}-{mode}",
                flops_per_request=2 * active * 1e9 * toks,
                weights_bytes=size * 2e9, vram_bytes=size * 3.2e9,
                sensitivity=Sensitivity.FREQUENCY if freq
                else Sensitivity.LATENCY,
                slo_latency_s=0.5 if freq else 2.0,
                slo_fps=24.0 if freq else 0.0)
            plan = allocate(svc, EDGE_P100)
            print(f"  {svc.name:22s} {str(plan.category):20s} "
                  f"TP{plan.mp} BS{plan.bs} MT{plan.mt} "
                  f"MF{plan.mf} DP{plan.dp}")

    # live HCI pattern: interaction interruptions switch to the newest
    # decode stream; DP groups serve alternating interactions
    print("\n== live HCI interruption demo (reduced model) ==")
    cfg = reduced(get_config("codeqwen1.5-7b"))
    params = model_api(cfg).init(jax.random.PRNGKey(0), cfg)
    hci_svc = ServiceSpec(
        name="hci", flops_per_request=1e9, weights_bytes=1e8,
        vram_bytes=2e8, sensitivity=Sensitivity.FREQUENCY, slo_fps=24.0,
        slo_latency_s=0.5)
    plan = allocate(hci_svc, EDGE_P100)
    rt = ServiceRuntime(cfg, params, plan)
    router = DPGroupRouter(plan)
    rng = np.random.default_rng(0)
    for interaction in range(3):
        group = router.route(session=interaction)
        rt.submit(GenerationRequest(
            rid=interaction,
            tokens=rng.integers(0, cfg.vocab_size, 5,
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=4, stream=interaction))
        out = rt.drain(max_wait_s=0.0)[0]   # slot loop: step until evicted
        arena = rt.groups[out.group].arena
        print(f"  interaction {interaction}: DP group {group}, "
              f"decode {list(out.tokens)} "
              f"({out.decode_s*1e3:.0f}ms decode, "
              f"{out.decode_steps} steps, arena "
              f"{arena.live}/{arena.capacity} slots after evict)")
    print(f"  fused decode compiled {rt.decode_traces}x across all "
          f"interactions (paged arena: one static shape)")
    print("done.")


if __name__ == "__main__":
    main()
