"""Train a ~100M-parameter model for a few hundred steps (deliverable b's
training driver): a reduced mamba2-family config through the full training
substrate — chunked loss, AdamW, checkpointing, synthetic pipeline.

  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    args = ap.parse_args()
    # d_model=512, 2 layers, d_ff=1536, vocab 4096 -> ~15M backbone; bump
    # layers for ~100M when you have the cycles:
    train.main(["--arch", args.arch, "--reduced", "--d-model", "512",
                "--layers", "2", "--steps", str(args.steps),
                "--batch", "8", "--seq", "256", "--lr", "3e-3",
                "--checkpoint", "/tmp/repro_train_small",
                "--log-every", "20"])


if __name__ == "__main__":
    main()
