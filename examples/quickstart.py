"""Quickstart: the EPARA pipeline end to end in one file.

1. Describe two edge AI services (a chat LLM, a video segmenter).
2. The task-categorized allocator picks (MP, BS, MT, MF, DP) per service.
3. SSSP places services on a 3-server edge cloud.
4. The distributed handler routes requests using ring-synced (stale) state.
5. A reduced JAX model actually serves the routed requests.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import (EdgeCloudControlPlane, Outcome, Request, ServerSpec,
                        ServiceSpec, Sensitivity)
from repro.models.registry import model_api
from repro.serving.engine import GenerationRequest, ServiceRuntime


def main():
    # 1) services with SLO contracts ------------------------------------
    services = {
        "llm-chat": ServiceSpec(
            "llm-chat", flops_per_request=2 * 2.7e9 * 256,
            weights_bytes=5.4e9, vram_bytes=8e9, slo_latency_s=2.0),
        "video-seg": ServiceSpec(
            "video-seg", flops_per_request=380e9, weights_bytes=1.3e8,
            vram_bytes=2e9, sensitivity=Sensitivity.FREQUENCY,
            slo_fps=60.0, slo_latency_s=0.2),
    }
    servers = [ServerSpec(sid=i, num_gpus=2) for i in range(3)]

    # 2) + 3) allocator and placement --------------------------------------
    cp = EdgeCloudControlPlane(servers, services)
    print("== task-categorized plans (Fig. 5 operators) ==")
    for name, plan in cp.plans.items():
        print(f"  {name:10s} -> {plan.category}  "
              f"MP={plan.mp} BS={plan.bs} MT={plan.mt} "
              f"MF={plan.mf} DP={plan.dp}")
    demand = {(s, n): 20.0 for s in services for n in range(3)}
    placements = cp.run_placement(demand)
    print(f"== SSSP placements == {placements}")

    # 4) sync + handler ----------------------------------------------------
    cp.publish_all(0.0)
    for _ in range(3):
        cp.sync_step(0.0)

    # 5) live data plane: a reduced dense model stands in for both services
    cfg = reduced(get_config("minicpm-2b"))
    params = model_api(cfg).init(jax.random.PRNGKey(0), cfg)
    runtimes = {}
    for svc, sid in placements:
        if sid >= 0:
            runtimes.setdefault(sid, {})[svc] = ServiceRuntime(
                cfg, params, cp.plans[svc])

    rng = np.random.default_rng(0)
    print("== serving (continuous batching over the paged KV arena) ==")
    for i in range(6):
        svc = list(services)[i % 2]
        req = Request(rid=i, service=svc, arrival_s=0.0, deadline_s=10.0)
        at = i % 3
        d = cp.handle(req, now=0.0, at_server=at)
        target = d.destination if d.outcome == Outcome.OFFLOAD else at
        if target not in runtimes or svc not in runtimes[target]:
            target = next(s for s, m in runtimes.items() if svc in m)
        rt = runtimes[target][svc]
        rt.submit(GenerationRequest(
            rid=i, tokens=rng.integers(0, cfg.vocab_size, 6,
                                       dtype=np.int32).astype(np.int32),
            max_new_tokens=4))
        # each step() = evict / admit / one fused decode step; drain runs
        # the loop until this request's slot is evicted (frequency services
        # hold frames for MF grouping; max_wait_s=0.0 flushes for the demo)
        res = rt.drain(max_wait_s=0.0)[0]
        print(f"  req{i} [{svc:9s}] {d.outcome.value:8s} -> server{target} "
              f"tokens={list(res.tokens)} "
              f"({res.prefill_s*1e3:.0f}ms prefill, "
              f"{res.decode_steps} decode steps)")

    # the arena data plane compiles one fused decode step per service and
    # never copies the live batch on admission — visible in the counters
    for sid, m in sorted(runtimes.items()):
        for svc, rt in m.items():
            print(f"  server{sid}/{svc}: {rt.decode_traces} decode "
                  f"compile(s), {rt.whole_cache_copies} whole-cache "
                  f"copies, {rt.admission_copy_bytes // 1024} KB copied, "
                  f"{rt.chunk_write_bytes // 1024} KB chunk-written")
    print("done.")


if __name__ == "__main__":
    main()
