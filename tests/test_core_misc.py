"""Allocator (§3.1/§4.1), goodput accounting, cost model, and control-plane
integration tests."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm
from repro.core.allocator import (DPGroupRouter, ParallelPlan, allocate,
                                  categorize, mesh_submesh, plan_goodput)
from repro.core.categories import (CAT_FREQ_MULTI, CAT_LAT_SINGLE, GPUSpec,
                                   Operator, Request, Sensitivity,
                                   ServerSpec, ServiceSpec, operators_for)
from repro.core.cluster import EdgeCloudControlPlane
from repro.core.goodput import GoodputMeter, frequency_credit

GPU = GPUSpec()


def _svc(name="s", gflops=50, weights_gb=0.5, vram_gb=1.0, freq=False,
         fps=30.0, lat=0.5, stateful=False):
    return ServiceSpec(
        name=name, flops_per_request=gflops * 1e9,
        weights_bytes=weights_gb * 1e9, vram_bytes=vram_gb * 1e9,
        sensitivity=Sensitivity.FREQUENCY if freq else Sensitivity.LATENCY,
        slo_latency_s=lat, slo_fps=fps if freq else 0.0, stateful=stateful)


# ---------------------------------------------------------------------------
# categorization + operator sets (Fig. 5)
# ---------------------------------------------------------------------------

def test_categorize_by_vram():
    small = _svc(vram_gb=1.0)
    big = _svc(vram_gb=100.0)
    assert not categorize(small, GPU).multi_gpu
    assert categorize(big, GPU).multi_gpu


def test_categorize_by_latency():
    slow = _svc(gflops=5e5, lat=0.01)   # cannot meet SLO on one GPU
    assert categorize(slow, GPU).multi_gpu


def test_operator_sets_match_fig5():
    assert operators_for(CAT_LAT_SINGLE) == {Operator.BS, Operator.MT}
    assert Operator.DP in operators_for(CAT_FREQ_MULTI)
    assert Operator.MF in operators_for(CAT_FREQ_MULTI)
    assert Operator.MP in operators_for(CAT_FREQ_MULTI)


def test_plan_respects_category_operators():
    plan = allocate(_svc(freq=False), GPU)
    assert plan.dp == 1 and plan.mf == 1          # latency task: no DP/MF
    plan_f = allocate(_svc(freq=True, vram_gb=100.0, gflops=5e4,
                           fps=10000.0), GPU)
    assert plan_f.category.multi_gpu


# ---------------------------------------------------------------------------
# Eq. 4 / Eq. 5
# ---------------------------------------------------------------------------

def test_dp_group_count_eq4():
    svc = _svc(freq=True, gflops=2e5, fps=120.0, lat=0.5, vram_gb=100.0)
    plan = allocate(svc, GPU)
    one_group = cm.throughput(svc, GPU, batch=plan.bs, mp=plan.mp,
                              mt=plan.mt)
    assert plan.dp == max(1, math.ceil(svc.slo_fps / one_group))


def test_inter_request_count_eq5():
    plan = ParallelPlan(service="s", category=CAT_FREQ_MULTI, bs=16, mf=4)
    assert plan.inter_request_count == 4
    plan = ParallelPlan(service="s", category=CAT_FREQ_MULTI, bs=16, mf=5)
    assert plan.inter_request_count == 3   # floor


@settings(max_examples=40, deadline=None)
@given(gflops=st.floats(1, 1e6), weights=st.floats(0.01, 400),
       freq=st.booleans(), fps=st.floats(1, 240), lat=st.floats(0.05, 5))
def test_allocate_invariants(gflops, weights, freq, fps, lat):
    """Property: plans are always internally consistent — operators allowed
    by the category, VRAM never overcommitted by MT, positive degrees."""
    svc = _svc(gflops=gflops, weights_gb=weights, vram_gb=weights * 1.2,
               freq=freq, fps=fps, lat=lat)
    plan = allocate(svc, GPU)
    assert plan.mp >= 1 and plan.bs >= 1 and plan.mt >= 1
    assert plan.dp >= 1 and plan.mf >= 1
    allowed = operators_for(plan.category)
    assert plan.operators() <= allowed
    assert cm.vram_fraction(svc, GPU, plan.mp) * plan.mt <= 1.0 + 1e-9
    if not freq:
        assert plan.dp == 1 and plan.mf == 1
    assert plan.mf <= plan.bs or plan.mf == 1


# ---------------------------------------------------------------------------
# DP router (request-level)
# ---------------------------------------------------------------------------

def test_dp_router_round_robin():
    plan = ParallelPlan(service="s", category=CAT_FREQ_MULTI, dp=3)
    r = DPGroupRouter(plan)
    assert [r.route() for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_dp_router_sticky_sessions():
    plan = ParallelPlan(service="s", category=CAT_FREQ_MULTI, dp=3,
                        sticky=True)
    r = DPGroupRouter(plan)
    g1 = r.route(session=42)
    g2 = r.route(session=43)
    assert r.route(session=42) == g1   # same session -> same group
    assert g2 != g1


def test_mesh_submesh_mapping():
    plan = ParallelPlan(service="s", category=CAT_FREQ_MULTI, dp=4, mp=2,
                        bs=8)
    mp = mesh_submesh(plan)
    assert mp.chips == 8 and mp.data_parallel == 4


# ---------------------------------------------------------------------------
# goodput accounting
# ---------------------------------------------------------------------------

def test_frequency_credit_paper_example():
    # 120 frames, SLO 60 fps, achieved 30 fps => 60 satisfied (§3.3)
    assert frequency_credit(120, 30.0, 60.0) == pytest.approx(60.0)
    assert frequency_credit(120, 90.0, 60.0) == pytest.approx(120.0)


def test_goodput_meter_windows():
    m = GoodputMeter()
    req = Request(rid=1, service="s", arrival_s=0.0, deadline_s=2.0)
    m.offered(req)
    m.complete_latency(req, finish_s=1.0)
    late = Request(rid=2, service="s", arrival_s=0.0, deadline_s=0.5)
    m.offered(late)
    m.complete_latency(late, finish_s=1.5)
    assert m.total_credit == 1.0 and m.violations == 1
    assert m.goodput("s", window=(0.0, 2.0)) == pytest.approx(0.5)
    assert m.goodput("s", window=(1.2, 2.0)) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# control plane integration
# ---------------------------------------------------------------------------

def test_control_plane_end_to_end():
    servers = [ServerSpec(sid=i, num_gpus=2) for i in range(3)]
    services = {"a": _svc("a"), "b": _svc("b", freq=True)}
    cp = EdgeCloudControlPlane(servers, services)
    demand = {(s, n): 20.0 for s in services for n in range(3)}
    theta = cp.run_placement(demand)
    assert theta
    cp.publish_all(0.0)
    for _ in range(3):
        cp.sync_step(0.0)
    req = Request(rid=1, service="a", arrival_s=0.0, deadline_s=5.0)
    d = cp.handle(req, now=0.1, at_server=0)
    assert d.outcome.value in ("local", "offload")


def test_device_registration_single_gpu_only():
    servers = [ServerSpec(sid=0, num_gpus=2)]
    services = {"small": _svc("small", vram_gb=1.0),
                "huge": _svc("huge", vram_gb=200.0)}
    cp = EdgeCloudControlPlane(servers, services)
    dev = cp.register_device(0, now=0.0)
    ready = cp.assign_device_service(dev.did, "small", now=0.0)
    assert ready > 0.0
    with pytest.raises(ValueError):
        cp.assign_device_service(dev.did, "huge", now=0.0)
    cp.deregister_device(dev.did)
    assert dev.did not in cp.devices
