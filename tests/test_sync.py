"""Ring synchronization (§3.4): propagation, staleness bounds, failure
bypass, silent-corruption recovery; plus the parameter-server backend."""
import pytest

from repro.core.handler import ServerView, ServiceState
from repro.core.sync import (ParameterServerSync, RingSynchronizer,
                             sync_round_seconds)


def _view(sid, goodput=10.0):
    return ServerView(sid=sid, services={
        "svc": ServiceState(theoretical_goodput=goodput)})


def _ring(n, **kw):
    return RingSynchronizer(list(range(n)), **kw)


def test_one_round_reaches_neighbors_only():
    ring = _ring(6)
    ring.publish_local(0, _view(0), now=0.0)
    ring.step(0.0)
    for sid in range(6):
        views = ring.views_for(sid, 0.0)
        if sid in (1, 5):
            assert 0 in views
        elif sid != 0:
            assert 0 not in views


def test_full_propagation_in_n_over_2_rounds():
    n = 8
    ring = _ring(n)
    for sid in range(n):
        ring.publish_local(sid, _view(sid), now=0.0)
    for r in range(n // 2):
        ring.step(float(r))
    for sid in range(n):
        views = ring.views_for(sid, 1.0)
        assert set(views) == set(range(n)) - {sid}


def test_staleness_bound_matches_ring_distance():
    ring = _ring(10, interval_s=2.0)
    b = ring.staleness_bound(0, 5)       # distance 5
    assert b == pytest.approx(5 * 2.0 + ring.round_cost_s)
    assert ring.staleness_bound(0, 9) == pytest.approx(
        1 * 2.0 + ring.round_cost_s)     # wraps around


def test_failure_bypass_and_flagging():
    ring = _ring(5)
    for sid in range(5):
        ring.publish_local(sid, _view(sid), now=0.0)
    ring.fail(2)
    for r in range(4):
        ring.step(float(r))
    views = ring.views_for(0, 1.0)
    # server 2's state is flagged unavailable; others still propagate
    if 2 in views:
        assert not views[2].available
    for sid in (1, 3, 4):
        assert sid in views and views[sid].available
    ring.repair(2)
    assert 2 not in ring.failed


def test_corruption_corrected_next_publish():
    ring = _ring(4)
    for sid in range(4):
        ring.publish_local(sid, _view(sid, goodput=10.0), now=0.0)
    for r in range(2):
        ring.step(float(r))
    ring.corrupt(1, factor=4.0)
    bad = ring.views_for(0, 1.0)[1].services["svc"].theoretical_goodput
    assert bad == pytest.approx(40.0)
    # next genuine publish + rounds wash it out
    ring.publish_local(1, _view(1, goodput=10.0), now=2.0)
    for r in range(2):
        ring.step(2.0 + r)
    good = ring.views_for(0, 3.0)[1].services["svc"].theoretical_goodput
    assert good == pytest.approx(10.0)


def test_corruption_heals_by_propagation_through_ring():
    """Fig. 19a: a corrupted digest is passively corrected — every holder
    of the bad entry gets overwritten once the NEXT genuine publish has
    propagated the full ring, with no explicit invalidation."""
    n = 6
    ring = _ring(n)
    for sid in range(n):
        ring.publish_local(sid, _view(sid, goodput=10.0), now=0.0)
    for r in range(n // 2):
        ring.step(float(r))
    ring.corrupt(3, factor=5.0)
    # every server currently believes the inflated figure
    holders = [s for s in range(n) if s != 3
               and ring.views_for(s, 1.0)[3]
               .services["svc"].theoretical_goodput > 10.0]
    assert len(holders) == n - 1
    ring.publish_local(3, _view(3, goodput=10.0), now=2.0)
    for r in range(n // 2):
        ring.step(2.0 + r)
    for s in range(n):
        if s == 3:
            continue
        g = ring.views_for(s, 5.0)[3].services["svc"].theoretical_goodput
        assert g == pytest.approx(10.0), f"server {s} still corrupted"


def test_ring_heals_and_staleness_grows_around_failed_server():
    """§5.3.3: a failed server is bypassed — the alive ring closes around
    it, so the analytic staleness bound between its ex-neighbours DROPS
    (they became adjacent) while the bound THROUGH the dead server is
    infinite.  Fresh digests keep flowing between survivors."""
    n = 6
    ring = _ring(n, interval_s=1.0)
    before = ring.staleness_bound(1, 3)          # distance 2 via server 2
    ring.fail(2)
    assert ring.staleness_bound(1, 2) == float("inf")
    assert ring.staleness_bound(2, 4) == float("inf")
    after = ring.staleness_bound(1, 3)           # now adjacent on the ring
    assert after < before
    # survivors still exchange: a post-failure publish reaches everyone
    for sid in range(n):
        if sid != 2:
            ring.publish_local(sid, _view(sid), now=10.0)
    for r in range(n // 2):
        ring.step(10.0 + r)
    for sid in range(n):
        if sid == 2:
            continue
        views = ring.views_for(sid, 12.0)
        assert set(range(n)) - {sid, 2} <= set(views)


def test_repair_rejoins_cold_and_relearns():
    """A restarted server lost its in-memory table: ``repair`` lifts the
    flag but clears its cache, so it rejoins COLD and re-learns peers one
    ring hop per round — while its own re-published digest propagates
    back out to them."""
    n = 5
    ring = _ring(n)
    for sid in range(n):
        ring.publish_local(sid, _view(sid), now=0.0)
    for r in range(n // 2):
        ring.step(float(r))
    assert len(ring.views_for(2, 1.0)) == n - 1
    ring.fail(2)
    ring.repair(2)
    assert 2 not in ring.failed
    assert ring.views_for(2, 5.0) == {}          # cold: table wiped
    ring.publish_local(2, _view(2, goodput=7.0), now=5.0)
    for r in range(n // 2):
        ring.step(5.0 + r)
    # re-learned its peers, and its fresh digest reached them
    assert set(ring.views_for(2, 8.0)) == {0, 1, 3, 4}
    g = ring.views_for(0, 8.0)[2].services["svc"].theoretical_goodput
    assert g == pytest.approx(7.0)


def test_repair_without_fail_keeps_cache():
    """Defensive: repairing a server that never failed must not wipe its
    table (restart bookkeeping only applies to actual corpses)."""
    ring = _ring(3)
    for sid in range(3):
        ring.publish_local(sid, _view(sid), now=0.0)
    ring.step(0.0)
    had = set(ring.views_for(0, 1.0))
    ring.repair(0)
    assert set(ring.views_for(0, 1.0)) == had


def test_round_cost_scales_with_servers_and_bandwidth():
    slow = sync_round_seconds(1000, 8, bandwidth_gbps=0.5)
    fast = sync_round_seconds(1000, 8, bandwidth_gbps=5.0)
    small = sync_round_seconds(100, 8, bandwidth_gbps=0.5)
    assert slow > fast and slow > small


def test_parameter_server_backend_flexibility():
    """§3.4: handler stays valid under a PS-style sync backend."""
    ps = ParameterServerSync([0, 1, 2], interval_s=0.5)
    for sid in range(3):
        ps.publish_local(sid, _view(sid), now=0.0)
    views = ps.views_for(0, 1.0)
    assert set(views) == {1, 2}
    assert views[1].sync_age_s >= 0.5
    ps.fail(2)
    assert not ps.views_for(0, 1.0)[2].available
