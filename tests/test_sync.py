"""Ring synchronization (§3.4): propagation, staleness bounds, failure
bypass, silent-corruption recovery; plus the parameter-server backend."""
import pytest

from repro.core.handler import ServerView, ServiceState
from repro.core.sync import (ParameterServerSync, RingSynchronizer,
                             sync_round_seconds)


def _view(sid, goodput=10.0):
    return ServerView(sid=sid, services={
        "svc": ServiceState(theoretical_goodput=goodput)})


def _ring(n, **kw):
    return RingSynchronizer(list(range(n)), **kw)


def test_one_round_reaches_neighbors_only():
    ring = _ring(6)
    ring.publish_local(0, _view(0), now=0.0)
    ring.step(0.0)
    for sid in range(6):
        views = ring.views_for(sid, 0.0)
        if sid in (1, 5):
            assert 0 in views
        elif sid != 0:
            assert 0 not in views


def test_full_propagation_in_n_over_2_rounds():
    n = 8
    ring = _ring(n)
    for sid in range(n):
        ring.publish_local(sid, _view(sid), now=0.0)
    for r in range(n // 2):
        ring.step(float(r))
    for sid in range(n):
        views = ring.views_for(sid, 1.0)
        assert set(views) == set(range(n)) - {sid}


def test_staleness_bound_matches_ring_distance():
    ring = _ring(10, interval_s=2.0)
    b = ring.staleness_bound(0, 5)       # distance 5
    assert b == pytest.approx(5 * 2.0 + ring.round_cost_s)
    assert ring.staleness_bound(0, 9) == pytest.approx(
        1 * 2.0 + ring.round_cost_s)     # wraps around


def test_failure_bypass_and_flagging():
    ring = _ring(5)
    for sid in range(5):
        ring.publish_local(sid, _view(sid), now=0.0)
    ring.fail(2)
    for r in range(4):
        ring.step(float(r))
    views = ring.views_for(0, 1.0)
    # server 2's state is flagged unavailable; others still propagate
    if 2 in views:
        assert not views[2].available
    for sid in (1, 3, 4):
        assert sid in views and views[sid].available
    ring.repair(2)
    assert 2 not in ring.failed


def test_corruption_corrected_next_publish():
    ring = _ring(4)
    for sid in range(4):
        ring.publish_local(sid, _view(sid, goodput=10.0), now=0.0)
    for r in range(2):
        ring.step(float(r))
    ring.corrupt(1, factor=4.0)
    bad = ring.views_for(0, 1.0)[1].services["svc"].theoretical_goodput
    assert bad == pytest.approx(40.0)
    # next genuine publish + rounds wash it out
    ring.publish_local(1, _view(1, goodput=10.0), now=2.0)
    for r in range(2):
        ring.step(2.0 + r)
    good = ring.views_for(0, 3.0)[1].services["svc"].theoretical_goodput
    assert good == pytest.approx(10.0)


def test_round_cost_scales_with_servers_and_bandwidth():
    slow = sync_round_seconds(1000, 8, bandwidth_gbps=0.5)
    fast = sync_round_seconds(1000, 8, bandwidth_gbps=5.0)
    small = sync_round_seconds(100, 8, bandwidth_gbps=0.5)
    assert slow > fast and slow > small


def test_parameter_server_backend_flexibility():
    """§3.4: handler stays valid under a PS-style sync backend."""
    ps = ParameterServerSync([0, 1, 2], interval_s=0.5)
    for sid in range(3):
        ps.publish_local(sid, _view(sid), now=0.0)
    views = ps.views_for(0, 1.0)
    assert set(views) == {1, 2}
    assert views[1].sync_age_s >= 0.5
    ps.fail(2)
    assert not ps.views_for(0, 1.0)[2].available
