"""Per-architecture smoke tests (deliverable f): reduced variants of every
assigned arch run one forward + one train step on CPU, asserting output
shapes and no NaNs; plus prefill/decode-step consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, long_context_variant, reduced
from repro.models.registry import model_api
from repro.training.optimizer import get_optimizer
from repro.training.train_step import make_train_step


def _reduced(arch):
    cfg = reduced(get_config(arch))
    if cfg.family == "moe":
        # high capacity so smoke routing never drops tokens (keeps the
        # decode == forward consistency check exact)
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    return dataclasses.replace(cfg, dtype="float32", param_dtype="float32")


def _batch(cfg, B=2, L=16, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, L)), jnp.int32)}
    if cfg.family == "audio":
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_len, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = _reduced(arch)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    hidden, aux = api.forward_hidden(params, cfg, batch)
    B, L = batch["tokens"].shape
    expect_L = L + (cfg.prefix_len if cfg.family == "vlm" else 0)
    assert hidden.shape == (B, expect_L, cfg.d_model)
    logits = api.logits_fn(params, cfg, hidden[:, -1])
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(hidden).any())
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = _reduced(arch)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    batch["labels"] = batch["tokens"]
    opt = get_optimizer("adamw", 1e-3)
    state = opt.init(params)
    step = make_train_step(cfg, opt, loss_chunk=8)
    new_params, _, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(new_params),
                        jax.tree.leaves(params)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Prefill L-3 tokens then decode 3 — logits must match the
    teacher-forced forward at each position (the serving-correctness
    invariant for every cache implementation)."""
    cfg = _reduced(arch)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    B, L = 2, 12
    batch = _batch(cfg, B=B, L=L)
    hidden, _ = api.forward_hidden(params, cfg, batch)
    full_logits = api.logits_fn(params, cfg, hidden)
    off = cfg.prefix_len if cfg.family == "vlm" else 0

    pre_batch = dict(batch, tokens=batch["tokens"][:, :L - 3])
    logits, cache = api.prefill(params, cfg, pre_batch, cache_size=L + 2)
    np.testing.assert_allclose(
        logits, full_logits[:, off + L - 4], rtol=2e-4, atol=2e-4)
    for t in range(L - 3, L):
        logits, cache = api.decode_step(params, cfg, batch["tokens"][:, t],
                                        cache)
        np.testing.assert_allclose(
            logits, full_logits[:, off + t], rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("arch", ["mistral-large-123b", "codeqwen1.5-7b"])
def test_long_context_variant_ring_cache(arch):
    """The long_500k SWA variant: ring cache decode == windowed forward."""
    cfg = dataclasses.replace(_reduced(arch), sliding_window=6)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=1, L=20)
    hidden, _ = api.forward_hidden(params, cfg, batch)
    full_logits = api.logits_fn(params, cfg, hidden)
    pre = dict(batch, tokens=batch["tokens"][:, :15])
    logits, cache = api.prefill(params, cfg, pre, cache_size=32)
    assert cache["k"].shape[2] == 6  # ring cache bounded by the window
    for t in range(15, 20):
        logits, cache = api.decode_step(params, cfg, batch["tokens"][:, t],
                                        cache)
        np.testing.assert_allclose(logits, full_logits[:, t], rtol=5e-4,
                                   atol=5e-4)


def test_long_context_variant_flags():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        lc = long_context_variant(cfg)
        assert lc.sub_quadratic, f"{arch} long variant not sub-quadratic"
        if cfg.sub_quadratic:
            assert lc == cfg  # natively sub-quadratic: untouched


def test_param_counts_match_nominal():
    expect = {
        "mistral-large-123b": (110e9, 135e9),
        "minitron-4b": (4e9, 6e9),
        "minicpm-2b": (2.4e9, 3.1e9),
        "grok-1-314b": (290e9, 340e9),
        "whisper-large-v3": (1.2e9, 2.0e9),
        "mixtral-8x7b": (44e9, 49e9),
        "paligemma-3b": (2.0e9, 3.2e9),
        "zamba2-7b": (6.0e9, 8.0e9),
        "mamba2-2.7b": (2.4e9, 3.2e9),
        "codeqwen1.5-7b": (6.5e9, 8.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo},{hi}]"


def test_moe_aux_loss_balanced_router():
    """Uniform router logits => aux loss ~= 1 (perfectly balanced)."""
    from repro.models import moe
    cfg = _reduced("mixtral-8x7b")
    probs = jnp.full((4, 32, cfg.num_experts), 1.0 / cfg.num_experts)
    combine, aux, dropped = moe._top_k_dispatch(probs, 2, capacity=32)
    assert combine.shape == (4, 32, cfg.num_experts, 32)
    assert float(dropped) == 0.0      # capacity 32 is never binding here
    # every token keeps exactly k gates (sum of combine weights == 1)
    sums = combine.sum(axis=(-2, -1))
    np.testing.assert_allclose(sums, 1.0, rtol=1e-5)


def test_fused_projections_consistency():
    """fused QKV + gate|up (the §Perf optimization) must preserve the
    prefill/decode == forward invariant."""
    cfg = dataclasses.replace(_reduced("codeqwen1.5-7b"),
                              fused_projections=True)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    assert "wqkv" in jax.tree_util.tree_leaves_with_path(params)[0][0][0].key \
        or True  # structural presence checked below
    flat = {"/".join(str(getattr(p, "key", p)) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(params)[0]}
    assert any("wqkv" in f for f in flat)
    assert any("w_gateup" in f for f in flat)
    batch = _batch(cfg, B=2, L=10)
    hidden, _ = api.forward_hidden(params, cfg, batch)
    full = api.logits_fn(params, cfg, hidden)
    lg, cache = api.prefill(params, cfg,
                            dict(batch, tokens=batch["tokens"][:, :8]),
                            cache_size=12)
    np.testing.assert_allclose(lg, full[:, 7], rtol=5e-4, atol=5e-4)
    lg, cache = api.decode_step(params, cfg, batch["tokens"][:, 8], cache)
    np.testing.assert_allclose(lg, full[:, 8], rtol=5e-4, atol=5e-4)


from hypothesis import given, settings, strategies as st


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), L=st.integers(4, 24),
       capacity=st.integers(1, 8))
def test_moe_dispatch_conservation(seed, L, capacity):
    """Property: per-token combine weights sum to 1 (kept) or 0 (dropped);
    no expert receives more than `capacity` tokens; dispatch is a subset
    of combine's support."""
    from repro.models import moe
    E, k = 4, 2
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed), (2, L, E)), -1)
    combine, aux, dropped = moe._top_k_dispatch(probs, k, capacity)
    sums = np.asarray(combine.sum(axis=(-2, -1)))
    assert np.all((np.abs(sums - 1.0) < 1e-4) | (np.abs(sums) < 1e-6))
    # the drop counter counts exactly the assignments past capacity
    kept = int((np.asarray(combine) > 0).sum())
    assert int(dropped) == 2 * L * k - kept
    # capacity: each (group, expert, slot) holds at most one token
    slot_occupancy = np.asarray((combine > 0).sum(axis=1))  # (G, E, C)
    assert slot_occupancy.max() <= 1
    per_expert = np.asarray((combine > 0).any(-1).sum(axis=1))
    assert per_expert.max() <= capacity * k  # k passes through capacity
    assert np.isfinite(float(aux))


def test_model_pallas_impl_matches_ref():
    """Whole-model cross-impl check: prefill+decode through the Pallas
    kernels (interpret) == the jnp reference path."""
    cfg = _reduced("mixtral-8x7b")   # exercises flash, decode AND moe_gemm
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=2, L=12)
    lg_ref, cache_ref = api.prefill(params, cfg,
                                    dict(batch,
                                         tokens=batch["tokens"][:, :10]),
                                    cache_size=14, impl="ref")
    lg_pl, cache_pl = api.prefill(params, cfg,
                                  dict(batch,
                                       tokens=batch["tokens"][:, :10]),
                                  cache_size=14, impl="pallas_interpret")
    np.testing.assert_allclose(lg_pl, lg_ref, rtol=2e-3, atol=2e-3)
    d_ref, _ = api.decode_step(params, cfg, batch["tokens"][:, 10],
                               cache_ref, impl="ref")
    d_pl, _ = api.decode_step(params, cfg, batch["tokens"][:, 10],
                              cache_pl, impl="pallas_interpret")
    np.testing.assert_allclose(d_pl, d_ref, rtol=2e-3, atol=2e-3)
