"""Event-driven simulator: determinism, EPARA vs baseline ordering on the
paper's standard scenario, offload bounds, scheduler policy surfaces."""
import pytest

from repro.core.categories import EDGE_P100, ServerSpec
from repro.simulator.baselines import SCHEDULERS, make_scheduler
from repro.simulator.engine import SimConfig, Simulation, run_comparison
from repro.simulator.workload import (WorkloadConfig, demand_matrix,
                                      generate_requests, table1_services)


@pytest.fixture(scope="module")
def scenario():
    services = table1_services()
    servers = [ServerSpec(sid=i, num_gpus=1, gpu=EDGE_P100)
               for i in range(4)]
    wl = WorkloadConfig(horizon_s=30.0, load_scale=20.0, seed=3)
    events = generate_requests(services, len(servers), wl)
    return services, servers, events


def test_workload_generation_stats():
    services = table1_services()
    wl = WorkloadConfig(horizon_s=30.0, load_scale=2.0, seed=0)
    events = generate_requests(services, 3, wl)
    assert len(events) > 100
    ts = [t for t, _, _ in events]
    assert ts == sorted(ts)
    assert all(0 <= sid < 3 for _, sid, _ in events)
    dm = demand_matrix(events, services, wl.horizon_s)
    assert all(v >= 0 for v in dm.values())
    freq = [r for _, _, r in events if r.duration_s > 0]
    assert freq and all(r.frames > 1 for r in freq)


def test_simulation_deterministic(scenario):
    services, servers, events = scenario
    cfg = SimConfig(horizon_s=30.0)
    runs = [Simulation(servers, services,
                       make_scheduler("EPARA", services, EDGE_P100, seed=1),
                       events, cfg).run() for _ in range(2)]
    assert runs[0].goodput == pytest.approx(runs[1].goodput)
    assert runs[0].violations == runs[1].violations


def test_epara_beats_baselines_under_load(scenario):
    services, servers, events = scenario
    res = run_comparison(servers, services, events,
                         ["EPARA", "InterEdge", "Galaxy", "SERV-P"],
                         SimConfig(horizon_s=30.0))
    ep = res["EPARA"].goodput
    for name in ("InterEdge", "Galaxy", "SERV-P"):
        assert ep >= res[name].goodput, \
            f"EPARA {ep} < {name} {res[name].goodput}"
    # the paper's headline: clear margin over the weakest baselines
    assert ep > 1.2 * res["SERV-P"].goodput


def test_offload_counts_bounded(scenario):
    services, servers, events = scenario
    sim = Simulation(servers, services,
                     make_scheduler("EPARA", services, EDGE_P100),
                     events, SimConfig(horizon_s=30.0))
    r = sim.run()
    assert all(c <= 5 for c in r.offload_counts)


def test_scheduler_policy_surfaces():
    services = table1_services(include_heavy=False)
    for name, cls in SCHEDULERS.items():
        sched = make_scheduler(name, services, EDGE_P100)
        for svc_name, plan in sched.plans.items():
            if not sched.request_level:
                assert plan.dp == 1 and plan.mf == 1, name
        if name == "Galaxy":
            assert all(p.bs == 1 and p.mt == 1
                       for p in sched.plans.values())
        if name == "SERV-P":
            assert sched.scheduling_latency(10) >= 0.05
            assert sched.scheduling_latency(40) == \
                sched.scheduling_latency(10)   # grouped at 10


def test_chunked_prefill_bounds_head_of_line_stall():
    """One long-prompt arrival mid-decode: unchunked, its whole prefill
    lands on the shared virtual queue and every live request's next token
    waits; chunked, the stall is capped at one chunk's worth of tokens —
    and goodput cannot get worse."""
    import dataclasses as dc

    from repro.core.categories import Request, ServerSpec, ServiceSpec
    from repro.simulator.engine import SimConfig, run_comparison

    servers = [ServerSpec(sid=0, num_gpus=2)]
    services = {"chat": ServiceSpec("chat", flops_per_request=5e9,
                                    weights_bytes=1e8, vram_bytes=3e8,
                                    slo_latency_s=0.5)}
    events, t = [], 0.0
    for i in range(60):
        t += 0.05
        # a steady stream of short prompts with one huge prompt mid-run
        prompt = 2000 if i == 30 else 16
        events.append((t, 0, Request(rid=i, service="chat", arrival_s=t,
                                     deadline_s=t + 0.5,
                                     prompt_tokens=prompt)))
    base = SimConfig(horizon_s=10.0, sync_interval_s=1.0,
                     prefill_token_s=1e-4)
    out = {}
    for name, chunk in (("unchunked", 0), ("chunked", 64)):
        cfg = dc.replace(base, prefill_chunk_tokens=chunk)
        out[name] = run_comparison(servers, services, events, ["EPARA"],
                                   cfg)["EPARA"]
    # per-step stall of live slots stays bounded by the chunk size ...
    assert out["chunked"].max_prefill_stall_s <= 64 * 1e-4 + 1e-9
    # ... while the unchunked baseline stalls for the whole long prompt
    assert out["unchunked"].max_prefill_stall_s >= 2000 * 1e-4 - 1e-9
    assert (out["chunked"].max_prefill_stall_s
            < out["unchunked"].max_prefill_stall_s)
    assert out["chunked"].goodput >= out["unchunked"].goodput


def test_stream_fps_cap_is_the_request_level_difference():
    """Fig. 1: without request-level DP one stream caps at a single group's
    rate; EPARA's cap is the whole deployment."""
    services = table1_services()
    heavy = services["deeplabv3p-vid"]
    ep = make_scheduler("EPARA", services, EDGE_P100)
    ie = make_scheduler("InterEdge", services, EDGE_P100)
    assert ep.stream_fps_cap(heavy) >= ie.stream_fps_cap(heavy)
