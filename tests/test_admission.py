"""Deadline-aware admission control (serving/admission.py): policy/verdict
surfaces, the slack cost model, composer reorder/shed, arena block-table
parking, preempt→resume bit-identity against a FIFO oracle, and a property
test that random overload interleavings never corrupt another slot's
decode output.  The fifo baseline must stay byte-inert."""
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import ParallelPlan
from repro.core.categories import (REJECT_VERDICTS, Outcome, Sensitivity,
                                   TaskCategory)
from repro.models import transformer as T
from repro.serving.admission import (ADMISSION_POLICIES,
                                     AdmissionController, ParkedEntry)
from repro.serving.arena import KVArena
from repro.serving.batching import BSComposer, MFComposer, QueuedItem
from repro.serving.engine import GenerationRequest, ServiceRuntime

from conftest import toy_config

LAT = TaskCategory(Sensitivity.LATENCY, False)
FREQ = TaskCategory(Sensitivity.FREQUENCY, False)


def _plan(bs=2, **kw):
    return ParallelPlan(service="t", category=LAT, bs=bs, **kw)


@pytest.fixture(scope="module")
def toy():
    cfg = toy_config()
    return cfg, T.init(jax.random.PRNGKey(0), cfg)


def _req(rid, max_new=4, deadline=0.0, prompt=4, stream=0):
    return GenerationRequest(
        rid=rid, tokens=np.arange(1, 1 + prompt, dtype=np.int32),
        max_new_tokens=max_new, deadline_s=deadline, stream=stream)


def _drain(rt, t, results, rejects, limit=2000.0):
    preempted = resumed = 0
    while rt.pending() or rt.in_flight():
        st_ = rt.step(now=t)
        results += st_.results
        rejects += st_.rejected
        preempted += st_.preempted
        resumed += st_.resumed
        t += 1.0
        assert t < limit, "engine failed to drain"
    return t, preempted, resumed


# ---------------------------------------------------------------------------
# controller unit surface (stub runtime — no engine, no jax)
# ---------------------------------------------------------------------------

class _StubRuntime:
    def __init__(self, slots=2, policy="sdf"):
        self.plan = _plan(bs=slots, admission=policy)
        self.composer = BSComposer(self.plan)
        self.prefill_chunk_tokens = 4
        self._slots = slots

    def total_slots(self):
        return self._slots


def test_policy_knob_validated():
    assert ADMISSION_POLICIES == ("fifo", "sdf")
    with pytest.raises(ValueError, match="admission policy"):
        AdmissionController(_StubRuntime(policy="edf"))
    # plan knob drives the default; fifo is inert
    ctrl = AdmissionController(_StubRuntime(policy="fifo"))
    assert not ctrl.active
    assert AdmissionController(_StubRuntime(policy="sdf")).active


def test_reject_verdicts_enum():
    assert set(REJECT_VERDICTS) == {Outcome.DEADLINE_MISSED,
                                    Outcome.CONGESTION, Outcome.OFFLOAD,
                                    Outcome.FAILED}
    assert Outcome.ADMIT not in REJECT_VERDICTS
    assert Outcome("deadline_missed") is Outcome.DEADLINE_MISSED


def test_cold_controller_admits_like_fifo():
    """Before any completion the EWMAs are 0: every estimate collapses to
    free, so only an already-expired deadline can shed."""
    ctrl = AdmissionController(_StubRuntime())
    live = _req(0, deadline=10.0)
    dead = _req(1, deadline=3.0)
    for rid, req in ((0, live), (1, dead)):
        ctrl.rt.composer.add(QueuedItem(payload=req, rid=rid))
    assert ctrl.service_estimate(live) == 0.0
    assert ctrl.wait_estimate(now=5.0) == 0.0
    dropped = ctrl.shed(now=5.0)
    assert [(it.rid, v) for it, v in dropped] == \
        [(1, Outcome.DEADLINE_MISSED)]
    assert ctrl.verdicts == {"deadline_missed": 1}


def test_cost_model_learns_caller_clock():
    ctrl = AdmissionController(_StubRuntime())
    for t in (0.0, 2.0, 4.0):
        ctrl.note_step(t)
    assert ctrl._round_dt == pytest.approx(2.0)

    class _Res:
        admitted_s, finished_s = 1.0, 11.0
    ctrl.observe(_Res())
    assert ctrl._svc_logical == pytest.approx(10.0)
    # 4 decode rounds + ceil(4/4) prefill chunk = 5 rounds of 2.0 each
    assert ctrl.service_estimate(_req(0)) == pytest.approx(10.0)
    assert ctrl.slack(_req(0, deadline=30.0), now=5.0) == pytest.approx(15.0)
    assert ctrl.slack(_req(0), now=5.0) == float("inf")
    # position-aware wait: head takes the next slot-turn, not the queue
    assert ctrl.wait_estimate(0.0, position=0) == pytest.approx(5.0)
    assert ctrl.wait_estimate(0.0, position=3) == pytest.approx(20.0)


def test_parked_request_owes_only_remaining_decode():
    ctrl = AdmissionController(_StubRuntime())
    ctrl.note_step(0.0)
    ctrl.note_step(1.0)
    req = _req(9, max_new=6)
    ctrl.note_park(ParkedEntry(
        req=req, group=0, blocks=[1, 2], emitted=[5, 6], cache_len=6,
        consumed=4, steps=2, prefill_s=0.0, admit_wall=0.0,
        decode_start_wall=0.0, admitted_s=0.0, parked_s=2.0))
    # 6 - 2 emitted = 4 remaining rounds; no prefill owed (KV is resident)
    assert ctrl.service_estimate(req) == pytest.approx(4.0)
    assert ctrl.parked_group(9) == 0
    assert ctrl.pop_parked(9).blocks == [1, 2]
    assert ctrl.pop_parked(9) is None


def test_pick_victim_guards():
    ctrl = AdmissionController(_StubRuntime())
    inf = float("inf")
    # deadline-less slots always qualify; laziest-then-longest preferred
    assert ctrl.pick_victim(2.0, [(inf, 3.0, "a"), (inf, 7.0, "b")]) == "b"
    # a victim must be strictly lazier than the urgent request
    assert ctrl.pick_victim(5.0, [(4.0, 1.0, "a")]) is None
    # ... and afford the round trip: slack >= urgent + own remaining
    assert ctrl.pick_victim(2.0, [(5.0, 4.0, "a")]) is None
    assert ctrl.pick_victim(2.0, [(6.0, 4.0, "a")]) == "a"


# ---------------------------------------------------------------------------
# composer admission surface
# ---------------------------------------------------------------------------

def test_bs_composer_reorder_and_shed():
    c = BSComposer(_plan(bs=4))
    for rid, dl in ((0, 9.0), (1, 3.0), (2, 6.0)):
        c.add(QueuedItem(payload=_req(rid, deadline=dl), rid=rid))
    c.reorder(lambda it: it.payload.deadline_s)
    assert [it.rid for it in c.queue] == [1, 2, 0]
    assert c.peek().rid == 1
    dropped = c.shed(lambda it: "late" if it.payload.deadline_s < 5 else None)
    assert [(it.rid, v) for it, v in dropped] == [(1, "late")]
    assert [it.rid for it in c.queue] == [2, 0]


def test_mf_composer_orders_across_streams_keeps_frame_order():
    plan = ParallelPlan(service="t", category=FREQ, bs=4, mf=2,
                        admission="sdf")
    c = MFComposer(plan)
    for rid, stream, dl in ((0, 1, 9.0), (1, 1, 9.0), (2, 2, 3.0),
                            (3, 2, 3.0)):
        c.add(QueuedItem(payload=_req(rid, deadline=dl, stream=stream),
                         stream=stream, rid=rid))
    c.reorder(lambda it: it.payload.deadline_s)
    assert c.peek().rid == 2          # urgent stream's head
    batch = c.compose(limit=2)
    # slack-ordered ACROSS streams, FIFO within: stream 2 drains first
    assert [it.rid for it in batch.items] == [2, 3]
    dropped = c.shed(lambda it: "v" if it.stream == 1 else None)
    assert [it.rid for it, _ in dropped] == [0, 1]
    assert 1 not in c.streams         # emptied stream is deleted


# ---------------------------------------------------------------------------
# arena block-table parking
# ---------------------------------------------------------------------------

def test_arena_park_keeps_blocks_and_frees_slot(dense_cfg):
    a = KVArena(dense_cfg, T.init_cache, capacity=2, max_seq_len=32,
                block_size=8)
    assert a.parkable
    s0 = a.alloc(20)                  # 3 blocks
    a.alloc(32)                       # other slot stays live
    blocks = list(a._slot_blocks[s0])
    parked = a.park(s0)
    assert parked == blocks and a.parks == 1
    assert a.parked_blocks == 3
    assert not a.occupancy()[s0]      # slot freed...
    assert (a.block_tables()[s0] == a.trash_block).all()
    assert all(a.block_ref(b) == 1 for b in parked)   # ...KV refs held
    # resume: stitch the parked blocks back, then drop the parked hold
    s1 = a.alloc(20, shared=parked)
    a.release_parked(parked)
    assert a.parked_blocks == 0
    assert list(a._slot_blocks[s1]) == blocks         # same physical KV
    assert all(a.block_ref(b) == 1 for b in parked)   # net refs unchanged
    a.set_len(s1, 13)
    assert int(a.lens[s1]) == 13


def test_arena_park_rejects_stateful_and_free_slots(dense_cfg):
    a = KVArena(dense_cfg, T.init_cache, capacity=2, max_seq_len=32,
                block_size=8)
    with pytest.raises(ValueError):
        a.park(0)                     # not occupied
    # abandoned parked blocks release back to the pool
    s0 = a.alloc(16)
    parked = a.park(s0)
    free0 = a.free_capacity
    a.release_parked(parked)
    assert a.free_capacity == free0 + len(parked)


def test_stateful_arena_not_parkable():
    cfg = toy_config(family="ssm", name="toy-ssm", ssm_state=4,
                     ssm_headdim=16)
    from repro.models.registry import model_api
    api = model_api(cfg)
    a = KVArena(cfg, api.init_cache, capacity=2, max_seq_len=32,
                block_size=8)
    assert a._state_shapes            # ssm keeps per-slot state leaves...
    assert not a.parkable             # ...which cannot survive slot reuse
    s0 = a.alloc(16)
    with pytest.raises(ValueError):
        a.park(s0)


# ---------------------------------------------------------------------------
# engine integration: verdicts, preemption, bit-identity
# ---------------------------------------------------------------------------

def test_expired_deadlines_get_verdicts_not_silent_drops(toy):
    cfg, params = toy
    rt = ServiceRuntime(cfg, params, _plan(bs=2, admission="sdf"))
    results, rejects = [], []
    for i in range(4):
        # deadlines already passed at submission time
        rt.submit(_req(i, deadline=1.0), now=5.0)
    t, _, _ = _drain(rt, 5.0, results, rejects)
    assert not results
    assert sorted(r.req.rid for r in rejects) == [0, 1, 2, 3]
    assert all(r.verdict is Outcome.DEADLINE_MISSED for r in rejects)
    assert rt.admission.verdicts["deadline_missed"] == 4
    # fifo serves the same requests dead — zero behavior change
    rt2 = ServiceRuntime(cfg, params, _plan(bs=2, admission="fifo"))
    results2, rejects2 = [], []
    for i in range(4):
        rt2.submit(_req(i, deadline=1.0), now=5.0)
    _drain(rt2, 5.0, results2, rejects2)
    assert len(results2) == 4 and not rejects2


def _run_policy(cfg, params, policy, preempt=True):
    """The preemption scenario: two lazy long decodes fill both slots,
    then an urgent tight-deadline request arrives.  Logical clock, one
    tick per engine round."""
    rt = ServiceRuntime(cfg, params, _plan(bs=2, admission=policy),
                        preempt=preempt)
    results, rejects, t = [], [], 0.0
    for i in range(2):                # warmup: learn the service EWMA
        rt.submit(_req(100 + i), now=t)
    t, _, _ = _drain(rt, t, results, rejects)
    for i in range(2):                # lazy: no deadline, long decode
        rt.submit(_req(i, max_new=30, prompt=6), now=t)
    for _ in range(2):
        rt.step(now=t)
        t += 1.0
    rt.submit(_req(7, deadline=t + 12.0), now=t)   # urgent but feasible
    t, preempted, resumed = _drain(rt, t, results, rejects)
    return ({r.rid: (list(map(int, r.tokens)), r.finished_s)
             for r in results}, rejects, preempted, resumed, rt)


def test_sdf_preempts_parks_and_resumes_bit_identically(toy):
    cfg, params = toy
    fifo, rej_f, pre_f, res_f, rt_f = _run_policy(cfg, params, "fifo")
    sdf, rej_s, pre_s, res_s, rt_s = _run_policy(cfg, params, "sdf")
    assert (pre_f, res_f, rej_f) == (0, 0, [])
    assert pre_s >= 1 and res_s == pre_s and not rej_s
    assert rt_f.decode_traces == rt_s.decode_traces == 1
    # the urgent request makes its deadline under sdf, misses under fifo
    assert sdf[7][1] <= 12.0 + 4.0 < fifo[7][1]
    # parked-then-resumed greedy decodes are bit-identical to never-parked
    assert set(fifo) == set(sdf)
    for rid in fifo:
        assert fifo[rid][0] == sdf[rid][0], f"rid {rid} tokens diverge"
    # parking flowed through the arena counters and left nothing behind
    arenas = [g.arena for g in rt_s.groups.values()]
    assert sum(a.parks for a in arenas) == pre_s
    assert all(a.parked_blocks == 0 and a.live == 0 for a in arenas)
    assert not rt_s.admission.parked


def test_no_preempt_flag_disables_parking(toy):
    cfg, params = toy
    _, rejects, preempted, _, _ = _run_policy(cfg, params, "sdf",
                                              preempt=False)
    assert preempted == 0
    # without parking the urgent head is still handled with a verdict or
    # served late — either way nothing disappears without one
    assert all(r.verdict in REJECT_VERDICTS for r in rejects)


# ---------------------------------------------------------------------------
# property: random overload interleavings never corrupt another slot
# ---------------------------------------------------------------------------

_EXAMPLES = int(os.environ.get("ADMISSION_EXAMPLES", "5"))

spec = st.tuples(
    st.integers(min_value=2, max_value=8),     # prompt tokens
    st.integers(min_value=1, max_value=8),     # max_new_tokens
    st.integers(min_value=0, max_value=4),     # arrival tick
    st.one_of(st.none(),                       # deadline budget from arrival
              st.floats(min_value=2.0, max_value=60.0)),
)


@settings(max_examples=_EXAMPLES, deadline=None)
@given(specs=st.lists(spec, min_size=3, max_size=10))
def test_random_interleavings_never_corrupt_outputs(specs):
    """Under arbitrary admit/shed/park/resume/evict interleavings on an
    overloaded 2-slot engine, every request that completes produces tokens
    BIT-IDENTICAL to the inert-FIFO oracle, and every submitted request is
    accounted for: served or rejected with exactly one verdict."""
    cfg = toy_config()
    params = T.init(jax.random.PRNGKey(0), cfg)

    def run(policy):
        rt = ServiceRuntime(cfg, params, _plan(bs=2, admission=policy))
        results, rejects, t = [], [], 0.0
        rt.submit(_req(1000), now=t)           # warmup: seed the EWMAs
        t, _, _ = _drain(rt, t, results, rejects)
        tick = 0
        pending = sorted(enumerate(specs), key=lambda x: x[1][2])
        while pending or rt.pending() or rt.in_flight():
            while pending and pending[0][1][2] <= tick:
                rid, (prompt, max_new, _, budget) = pending.pop(0)
                rt.submit(_req(rid, max_new=max_new, prompt=prompt,
                               deadline=0.0 if budget is None
                               else t + budget), now=t)
            st_ = rt.step(now=t)
            results += st_.results
            rejects += st_.rejected
            t += 1.0
            tick += 1
            assert t < 3000.0, "engine failed to drain"
        assert rt.decode_traces == 1
        return rt, results, rejects

    _, oracle, oracle_rej = run("fifo")
    rt, results, rejects = run("sdf")
    assert not oracle_rej
    # accounting: no verdict-less drops (warmup included in results)
    assert len(results) + len(rejects) == len(specs) + 1
    assert len({r.rid for r in results} | {r.req.rid for r in rejects}) \
        == len(specs) + 1
    assert all(r.verdict in REJECT_VERDICTS for r in rejects)
    # bit-identity: whatever completed matches the never-shed oracle
    want = {r.rid: list(map(int, r.tokens)) for r in oracle}
    for r in results:
        assert list(map(int, r.tokens)) == want[r.rid], \
            f"rid {r.rid} corrupted by admission interleaving"
    # nothing left parked; every arena drained clean
    assert not rt.admission.parked
    assert all(g.arena.parked_blocks == 0 and g.arena.live == 0
               for g in rt.groups.values() if g.arena is not None)


# ---------------------------------------------------------------------------
# simulator: fluid-flow sdf model
# ---------------------------------------------------------------------------

def test_simulator_sdf_sheds_doomed_and_counts_verdicts():
    from repro.core.categories import EDGE_P100, ServerSpec
    from repro.simulator.baselines import make_scheduler
    from repro.simulator.engine import SimConfig, Simulation
    from repro.simulator.workload import (WorkloadConfig, generate_requests,
                                          table1_services)
    services = table1_services()
    servers = [ServerSpec(sid=i, num_gpus=1, gpu=EDGE_P100)
               for i in range(2)]
    wl = WorkloadConfig(horizon_s=20.0, load_scale=40.0, seed=3)
    events = generate_requests(services, len(servers), wl)

    def run(policy):
        sched = make_scheduler("EPARA", services, EDGE_P100, seed=1)
        return Simulation(servers, services, sched, events,
                          SimConfig(horizon_s=20.0,
                                    admission_policy=policy)).run()

    fifo, sdf = run("fifo"), run("sdf")
    assert fifo.verdicts == {} and fifo.preemptions == 0
    # sdf sheds requests that cannot make their deadline instead of
    # burning capacity on them: goodput never degrades under overload
    assert sdf.goodput >= fifo.goodput
    assert sdf.verdicts.get("deadline_missed", 0) + \
        sdf.verdicts.get("admit", 0) > 0
    with pytest.raises(ValueError, match="admission_policy"):
        Simulation(servers, services,
                   make_scheduler("EPARA", services, EDGE_P100),
                   events, SimConfig(admission_policy="edf"))
