"""End-to-end system behaviour: the full EPARA pipeline — allocator ->
placement -> sync -> handler -> live JAX serving — plus the launchers'
public entry points."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core import (EdgeCloudControlPlane, Outcome, Request, ServerSpec,
                        ServiceSpec, Sensitivity)
from repro.models.registry import model_api
from repro.serving.engine import (EparaServingEngine, GenerationRequest,
                                  ServiceRuntime)


def _specs():
    return {
        "chat": ServiceSpec("chat", flops_per_request=1e10,
                            weights_bytes=2e8, vram_bytes=5e8,
                            slo_latency_s=1.0),
        "video": ServiceSpec("video", flops_per_request=5e9,
                             weights_bytes=1e8, vram_bytes=3e8,
                             sensitivity=Sensitivity.FREQUENCY,
                             slo_fps=30.0, slo_latency_s=0.2),
    }


def test_full_pipeline_serves_requests(dense_cfg):
    servers = [ServerSpec(sid=i, num_gpus=2) for i in range(2)]
    cp = EdgeCloudControlPlane(servers, _specs())
    demand = {(s, n): 10.0 for s in _specs() for n in range(2)}
    placements = cp.run_placement(demand)
    assert placements
    cp.publish_all(0.0)
    for _ in range(2):
        cp.sync_step(0.0)

    # live data plane: toy dense model stands in for both services
    params = model_api(dense_cfg).init(jax.random.PRNGKey(0), dense_cfg)
    engines = {s.sid: EparaServingEngine() for s in servers}
    for svc, sid in placements:
        if sid >= 0:
            engines[sid].deploy(svc, ServiceRuntime(dense_cfg, params,
                                                    cp.plans[svc]))
    served = 0
    for i in range(6):
        svc = list(_specs())[i % 2]
        req = Request(rid=i, service=svc, arrival_s=0.0, deadline_s=100.0)
        d = cp.handle(req, now=0.0, at_server=i % 2)
        assert d.outcome in (Outcome.LOCAL, Outcome.OFFLOAD,
                             Outcome.LOCAL_CROSS)
        target = d.destination if d.outcome == Outcome.OFFLOAD else i % 2
        if svc not in engines[target].runtimes:
            target = next(s for s, e in engines.items()
                          if svc in e.runtimes)
        engines[target].submit(svc, GenerationRequest(
            rid=i, tokens=np.arange(4, dtype=np.int32), max_new_tokens=2))
        served += 1
    results = []
    for e in engines.values():
        results.extend(e.drain())
    assert len(results) == served
    assert all(len(r.tokens) == 2 for r in results)


def test_serve_launcher_main():
    from repro.launch import serve
    rc = serve.main(["--archs", "codeqwen1.5-7b", "--servers", "2",
                     "--requests", "4", "--max-new-tokens", "2"])
    assert rc == 0


def test_train_launcher_main():
    from repro.launch import train
    rc = train.main(["--arch", "minicpm-2b", "--reduced", "--steps", "3",
                     "--batch", "2", "--seq", "32", "--log-every", "2"])
    assert rc == 0


def test_reduced_configs_are_smoke_sized():
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        assert cfg.num_layers <= 2 or cfg.family == "hybrid"
        assert cfg.d_model <= 512
        if cfg.family == "moe":
            assert cfg.num_experts <= 4
