"""Roofline machinery: the trip-count-aware HLO cost analyzer on known
programs, collective wire factors, analytic traffic model sanity."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.config import SHAPES_BY_NAME
from repro.roofline.analysis import Roofline, model_flops_estimate
from repro.roofline.analytic import traffic
from repro.roofline.hlo_cost import HloCostModel, analyze_hlo_text


def test_nested_scan_flops_exact():
    x = jnp.zeros((64, 64))
    w = jnp.zeros((5, 64, 64))

    def f(x, w):
        def inner(c, wi):
            c2 = jax.lax.scan(lambda a, _: (a @ wi, None), c,
                              jnp.arange(3))[0]
            return c2, None
        return jax.lax.scan(inner, x, w)[0]

    c = jax.jit(f).lower(x, w).compile()
    cost = analyze_hlo_text(c.as_text())
    assert cost.flops == pytest.approx(2 * 64 ** 3 * 15, rel=1e-6)


def test_unrolled_matches_xla():
    x = jnp.zeros((32, 32))

    def f(x):
        for _ in range(4):
            x = x @ x
        return x

    c = jax.jit(f).lower(x).compile()
    cost = analyze_hlo_text(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older JAX wraps the dict in a list
        ca = ca[0]
    assert cost.flops == pytest.approx(float(ca["flops"]), rel=0.05)


def test_collective_wire_factors():
    hlo = """
HloModule m, is_scheduled=true

ENTRY %main (p: f32[64,128]) -> f32[64,128] {
  %p = f32[64,128]{1,0} parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[64,128]{1,0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %cp = f32[64,128]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
}
"""
    cost = analyze_hlo_text(hlo)
    bytes_ = 64 * 128 * 4
    want = bytes_ * (2 * 3 / 4) + bytes_ * (3 / 4) + bytes_ * 1.0
    assert cost.coll_wire_bytes == pytest.approx(want)
    assert cost.coll_counts == {"all-reduce": 1, "all-gather": 1,
                                "collective-permute": 1}


def test_while_trip_count_multiplies_collectives():
    hlo = """
HloModule m

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%add
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128]{0} parameter(0)
  %c = s32[] constant(0)
  %t0 = (s32[], f32[128]) tuple(%c, %a)
  %w = (s32[], f32[128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[128]{0} get-tuple-element(%w), index=1
}
"""
    cost = analyze_hlo_text(hlo)
    assert cost.coll_counts["all-reduce"] == 7
    assert cost.coll_wire_bytes == pytest.approx(7 * 128 * 4 * (2 * 3 / 4))


def test_roofline_dominant_and_ratio():
    r = Roofline(name="x", chips=4, flops_per_device=197e12,
                 bytes_per_device=819e9 * 2, collective_wire_bytes=50e9 / 2,
                 collective_counts={}, memory_stats={}, model_flops=197e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.25)


def test_model_flops_estimate_rules():
    cfg = get_config("mixtral-8x7b")
    tr = model_flops_estimate(cfg, SHAPES_BY_NAME["train_4k"])
    pf = model_flops_estimate(cfg, SHAPES_BY_NAME["prefill_32k"])
    dc = model_flops_estimate(cfg, SHAPES_BY_NAME["decode_32k"])
    n_active = cfg.active_param_count()
    assert tr == pytest.approx(6 * n_active * 4096 * 256)
    assert pf == pytest.approx(2 * n_active * 32768 * 32)
    assert dc == pytest.approx(2 * n_active * 128)


@pytest.mark.parametrize("shape_name", list(SHAPES_BY_NAME))
def test_analytic_traffic_positive_and_ordered(shape_name):
    cfg = get_config("codeqwen1.5-7b")
    shape = SHAPES_BY_NAME[shape_name]
    tb = traffic(cfg, shape, data_ax=16, model_ax=16)
    assert tb.total > 0
    # more chips on the model axis must not increase per-device traffic
    tb_wide = traffic(cfg, shape, data_ax=16, model_ax=32)
    assert tb_wide.total <= tb.total * 1.01


def test_hlo_parser_handles_tuple_shapes_with_comments():
    hlo = """
HloModule m

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %t = (f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}) tuple(%a, %a, %a, %a, %a, %a)
  ROOT %g = f32[8]{0} get-tuple-element(%t), index=5
}
"""
    model = HloCostModel(hlo)
    assert model.entry == "main"
    instrs = {i.name: i for i in model.computations["main"]}
    assert "t" in instrs and instrs["t"].opcode == "tuple"
