"""Chunked piggybacked prefill: family-parity harness (chunked prefill
must produce the same greedy tokens as one-shot prefill across all six
model families and both kvcache impls), the chunk-attention kernels, the
arena's multi-token append, and the truthful-timing fix.

The property test drives random admit/chunk/decode schedules through the
serving engine; ``CHUNKED_PREFILL_EXAMPLES`` scales the example budget
(the CI hypothesis-profile job raises it on a fixed seed)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models.registry import model_api
from repro.serving.engine import GenerationRequest, ServiceRuntime

from conftest import toy_config

LAT = TaskCategory(Sensitivity.LATENCY, False)
FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
_EXAMPLES = int(os.environ.get("CHUNKED_PREFILL_EXAMPLES", "6"))


def _family_cfg(family):
    """Tiny per-family config.  MoE runs at high capacity factor: chunked
    prefill legitimately changes the routing-group granularity, so exact
    parity is only guaranteed while expert capacity is not binding."""
    over = dict(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=97)
    if family == "moe":
        over.update(num_experts=4, experts_per_token=2,
                    moe_capacity_factor=8.0)
    elif family in ("ssm", "hybrid"):
        over.update(ssm_state=4, ssm_headdim=16)
        if family == "hybrid":
            over.update(attn_every=1)
    elif family == "audio":
        over.update(encoder_layers=1, encoder_len=8)
    elif family == "vlm":
        over.update(prefix_len=4)
    return toy_config(family=family, **over)


_CFGS = {f: _family_cfg(f) for f in FAMILIES}
_PARAMS = {}


def _family_params(family):
    if family not in _PARAMS:
        _PARAMS[family] = model_api(_CFGS[family]).init(
            jax.random.PRNGKey(7), _CFGS[family])
    return _PARAMS[family]


def _requests(cfg, rng, n_reqs):
    reqs = []
    for i in range(n_reqs):
        plen = int(rng.integers(1, 13))
        n = int(rng.integers(1, 5))
        extras = None
        if cfg.family in ("audio", "vlm"):
            dim = cfg.encoder_len if cfg.family == "audio" else cfg.prefix_len
            extras = {"embeddings": rng.normal(
                size=(dim, cfg.d_model)).astype(np.float32)}
        reqs.append(GenerationRequest(
            rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                       plen).astype(np.int32),
            max_new_tokens=n, extras=extras))
    return reqs


def _serve(cfg, params, reqs, **kw):
    rt = ServiceRuntime(cfg, params, ParallelPlan(service="t", category=LAT,
                                                  bs=kw.pop("bs", 2)),
                        max_seq_len=48, block_size=8, **kw)
    for r in reqs:
        rt.submit(r)
    return rt, {r.rid: list(r.tokens) for r in rt.drain()}


# ---------------------------------------------------------------------------
# family parity: chunked <=> one-shot, across both kvcache impls
# ---------------------------------------------------------------------------

@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(family=st.sampled_from(FAMILIES), seed=st.integers(0, 2 ** 16),
       bs=st.integers(1, 3))
def test_chunked_prefill_matches_one_shot_across_families(family, seed, bs):
    """Random admit/evict schedules with mixed prompt lengths must yield
    IDENTICAL greedy tokens whether prompts are prefilled in one shot
    (paged or dense impl) or chunk-by-chunk through the arena's block
    tables — for every model family."""
    cfg, params = _CFGS[family], _family_params(family)
    rng = np.random.default_rng(seed)
    reqs = _requests(cfg, rng, n_reqs=4)
    _, chunked = _serve(cfg, params, reqs, bs=bs, kvcache_impl="paged")
    _, oneshot = _serve(cfg, params, reqs, bs=bs, kvcache_impl="paged",
                        chunked_prefill=False)
    _, dense = _serve(cfg, params, reqs, bs=bs, kvcache_impl="dense")
    assert chunked == oneshot, (family, seed)
    assert chunked == dense, (family, seed)


@pytest.mark.parametrize("family", FAMILIES)
def test_prefill_chunk_chain_matches_prefill_logits(family):
    """Model-level harness (no engine): chaining ``prefill_chunk`` over a
    prompt reproduces one-shot ``prefill``'s final logits and its greedy
    continuation, including uneven final chunks."""
    cfg, params = _CFGS[family], _family_params(family)
    api = model_api(cfg)
    rng = np.random.default_rng(3)
    L, S = 11, 32
    prompt = rng.integers(1, cfg.vocab_size, L).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompt[None])}
    if cfg.family in ("audio", "vlm"):
        dim = cfg.encoder_len if cfg.family == "audio" else cfg.prefix_len
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(1, dim, cfg.d_model)), jnp.float32)
    extra = cfg.prefix_len if cfg.family == "vlm" else 0
    want, cache1 = api.prefill(params, cfg, batch, cache_size=S - extra)

    cache = api.init_cache(cfg, 1, S)
    pos = 0
    for j, bucket in enumerate((4, 4, 4)):       # 4+4+3: ragged final chunk
        cl = min(bucket, L - pos)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :cl] = prompt[pos:pos + cl]
        b = {"tokens": jnp.asarray(toks)}
        if j == 0 and "embeddings" in batch:
            b["embeddings"] = batch["embeddings"]
        got, cache = api.prefill_chunk(params, cfg, b, cache, chunk_len=cl)
        pos += cl
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert int(cache["len"]) == L + extra
    t1 = jnp.argmax(want, -1).astype(jnp.int32)
    t2 = jnp.argmax(got, -1).astype(jnp.int32)
    for _ in range(3):                           # caches decode identically
        l1, cache1 = api.decode_step(params, cfg, t1, cache1)
        l2, cache = api.decode_step(params, cfg, t2, cache)
        t1 = jnp.argmax(l1, -1).astype(jnp.int32)
        t2 = jnp.argmax(l2, -1).astype(jnp.int32)
        assert int(t1[0]) == int(t2[0]), family


# ---------------------------------------------------------------------------
# chunk-attention kernels: ref vs exact, Pallas (interpret) vs ref
# ---------------------------------------------------------------------------

def test_chunk_attention_ref_matches_exact_chain(rng):
    from repro.kernels import ref
    B, S, Hq, Hkv, D, L = 2, 32, 4, 2, 16, 20
    q = rng.normal(size=(B, L, Hq, D)).astype(np.float32)
    k = rng.normal(size=(B, L, Hkv, D)).astype(np.float32)
    v = rng.normal(size=(B, L, Hkv, D)).astype(np.float32)
    want = ref.mha_exact(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=True)
    kc = np.zeros((B, S, Hkv, D), np.float32)
    vc = np.zeros_like(kc)
    outs = []
    for lo, hi in ((0, 8), (8, 16), (16, 20)):
        T, cl = 8, hi - lo
        qch = np.zeros((B, T, Hq, D), np.float32)
        qch[:, :cl] = q[:, lo:hi]
        kc[:, lo:hi] = k[:, lo:hi]
        vc[:, lo:hi] = v[:, lo:hi]
        out = ref.chunk_attention_ref(jnp.asarray(qch), jnp.asarray(kc),
                                      jnp.asarray(vc), lo, cl)
        outs.append(np.asarray(out)[:, :cl])
    np.testing.assert_allclose(np.concatenate(outs, axis=1),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunk_prefill_attention_pallas_matches_ref(rng):
    from repro.kernels import ref
    from repro.kernels.decode_attention import chunk_prefill_attention_pallas
    B, S, T, Hq, Hkv, D = 2, 40, 8, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, Hq, D)).astype(np.float32))
    kc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    start = jnp.asarray(np.array([5, 17], np.int32))
    cl = jnp.asarray(np.array([8, 3], np.int32))
    want = ref.chunk_attention_ref(q, kc, vc, start, cl)
    got = chunk_prefill_attention_pallas(q, kc, vc, start, cl,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_chunk_prefill_attention_matches_gathered_ref(rng):
    from repro.kernels import ref
    from repro.kernels.decode_attention import (
        paged_chunk_prefill_attention_pallas, paged_gather_ref)
    B, T, Hq, Hkv, D, bs, nblk, P = 2, 8, 4, 2, 16, 8, 4, 10
    q = jnp.asarray(rng.normal(size=(B, T, Hq, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P + 1, bs, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P + 1, bs, Hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.permutation(P)[:B * nblk].reshape(B, nblk)
                     .astype(np.int32))
    start = jnp.asarray(np.array([4, 19], np.int32))
    cl = jnp.asarray(np.array([8, 6], np.int32))
    want = ref.chunk_attention_ref(q, paged_gather_ref(kp, bt),
                                   paged_gather_ref(vp, bt), start, cl)
    got = paged_chunk_prefill_attention_pallas(q, kp, vp, bt, start, cl,
                                               interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ops_chunk_attention_dispatch(rng):
    from repro.kernels import ops
    B, S, T, Hq, Hkv, D = 1, 16, 4, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(B, T, Hq, D)).astype(np.float32))
    kc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    out = ops.chunk_attention(q, kc, vc, 2, 4, impl="ref")
    assert out.shape == (B, T, Hq, D)
    assert np.isfinite(np.asarray(out)).all()
    kp = jnp.asarray(rng.normal(size=(5, 8, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(5, 8, Hkv, D)).astype(np.float32))
    bt = jnp.asarray(np.array([[0, 1]], np.int32))
    out = ops.paged_chunk_attention(q, kp, vp, bt, jnp.asarray([2]),
                                    jnp.asarray([4]), impl="ref")
    assert out.shape == (B, T, Hq, D)


# ---------------------------------------------------------------------------
# arena: multi-token append (write_prefill's offset/partial mode)
# ---------------------------------------------------------------------------

def test_arena_append_rows_multi_token_matches_write_prefill(dense_cfg):
    """Writing a prompt chunk-by-chunk through the multi-token
    ``append_rows`` reconstructs the same pages as one-shot
    ``write_prefill`` — including unaligned chunk starts."""
    from repro.models import transformer as T
    from repro.serving.arena import KVArena

    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    prompt = jnp.asarray(np.arange(1, 14, dtype=np.int32)[None])   # L=13
    a1 = KVArena(dense_cfg, T.init_cache, capacity=2, max_seq_len=32,
                 block_size=8)
    _, cache = T.prefill(params, dense_cfg, {"tokens": prompt},
                         cache_size=a1.slot_tokens)
    s1 = a1.alloc(20)
    a1.write_prefill(s1, cache, prompt_len=13)

    a2 = KVArena(dense_cfg, T.init_cache, capacity=2, max_seq_len=32,
                 block_size=8)
    s2 = a2.alloc(20)
    bt = jnp.asarray(a2.block_tables()[s2][None])
    lens = jnp.zeros((1,), jnp.int32)
    for lo, hi in ((0, 5), (5, 13)):           # 5 is NOT block-aligned
        n = hi - lo
        dense = [jnp.zeros((leaf.shape[0], 1, a2.slot_tokens,
                            *leaf.shape[3:]), leaf.dtype)
                 for leaf in (cache["k"], cache["v"])]
        dense = [d.at[:, :, lo:hi].set(src[:, :, lo:hi]) for d, src in
                 zip(dense, (cache["k"], cache["v"]))]
        a2.pages = a2.append_rows(
            a2.pages, dense, lens + lo, jnp.ones((1,), bool), bt,
            n_tokens=n, valid_tokens=jnp.asarray([n]))
    v1 = a1.dense_view(a1.pages, jnp.asarray(a1.block_tables()[s1][None]))
    v2 = a2.dense_view(a2.pages, bt)
    for x, y in zip(v1, v2):
        np.testing.assert_allclose(np.asarray(x[:, :, :13]),
                                   np.asarray(y[:, :, :13]), rtol=1e-6)


# ---------------------------------------------------------------------------
# truthful timings under chunking (the decode_start_wall fix)
# ---------------------------------------------------------------------------

def test_decode_timing_excludes_chunked_prefill(dense_cfg):
    """A request that finishes on its first token (max_new_tokens=1) spends
    its whole life in prefill: ``decode_s`` must be exactly 0 even though
    several chunked steps elapsed between admission and the first token
    (the old code stamped decode_start_wall at admit time)."""
    from repro.models import transformer as T
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = ServiceRuntime(dense_cfg, params,
                        ParallelPlan(service="t", category=LAT, bs=2),
                        max_seq_len=64, block_size=8)
    rt.submit(GenerationRequest(rid=0,
                                tokens=np.arange(1, 50, dtype=np.int32),
                                max_new_tokens=1))
    res = rt.drain()
    assert len(res) == 1
    assert res[0].prefill_s > 0.0
    assert res[0].decode_s == 0.0
    assert rt.prefill_chunk_calls >= 3          # 49 tokens, 16-token budget


def test_step_stats_report_chunk_tokens(dense_cfg):
    """StepStats.prefill_chunk_tokens accounts every prompt token exactly
    once, and in-progress prefills hold their slot (in_flight) without
    decoding."""
    from repro.models import transformer as T
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = ServiceRuntime(dense_cfg, params,
                        ParallelPlan(service="t", category=LAT, bs=2),
                        max_seq_len=64, block_size=8)
    prompt = np.arange(1, 40, dtype=np.int32)          # 39 tokens > budget
    rt.submit(GenerationRequest(rid=0, tokens=prompt, max_new_tokens=2))
    stats = rt.step()
    assert stats.admitted == 1 and stats.in_flight == 1
    assert 0 < stats.prefill_chunk_tokens < len(prompt)
    assert stats.decode_steps == 0              # nothing decodable yet
    total = stats.prefill_chunk_tokens
    while rt.pending() or rt.in_flight():
        stats = rt.step()
        total += stats.prefill_chunk_tokens
    assert total == len(prompt)
