"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property tests in this suite use a small strategy surface
(integers/floats/booleans/none/sampled_from/one_of/lists).  When the real
``hypothesis`` is available nothing here is used; otherwise ``install()``
registers a minimal shim under ``sys.modules['hypothesis']`` so the test
modules import unchanged and each ``@given`` test runs against a fixed
number of seeded pseudo-random examples instead of being skipped at
collection time.  Failures reproduce exactly (the draw sequence depends
only on the test name), they just lack hypothesis' shrinking.
"""
from __future__ import annotations

import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def none():
    return _Strategy(lambda rng: None)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def one_of(*strategies):
    return _Strategy(
        lambda rng: strategies[rng.randrange(len(strategies))].example(rng))


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def just(value):
    return _Strategy(lambda rng: value)


_PROFILES = {}
_ACTIVE_PROFILE = {}


def settings(max_examples=None, **_kw):
    def deco(fn):
        n = max_examples
        if n is None:
            n = _ACTIVE_PROFILE.get("max_examples", _DEFAULT_MAX_EXAMPLES)
        fn._fallback_max_examples = n
        return fn
    return deco


def _register_profile(name, max_examples=_DEFAULT_MAX_EXAMPLES, **kw):
    """Shim twin of ``hypothesis.settings.register_profile`` — only
    ``max_examples`` is honored (the shim is already deterministic, so
    ``derandomize``/``print_blob`` are no-ops)."""
    _PROFILES[name] = dict(max_examples=max_examples, **kw)


def _load_profile(name):
    _ACTIVE_PROFILE.clear()
    _ACTIVE_PROFILE.update(_PROFILES.get(name, {}))


settings.register_profile = _register_profile
settings.load_profile = _load_profile


def given(*args, **strategies):
    assert not args, "fallback @given supports keyword strategies only"

    def deco(fn):
        def runner():
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            n = getattr(runner, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                kwargs = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(**kwargs)
                except _Unsatisfied:
                    continue
                except Exception as e:  # noqa: BLE001 — annotate the example
                    raise AssertionError(
                        f"falsifying example (fallback draw {i}): "
                        f"{kwargs!r}") from e

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "none", "sampled_from",
                 "one_of", "lists", "tuples", "just"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
