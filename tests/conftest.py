"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests and benches
run with the real single CPU device; only launch/dryrun.py forces 512
placeholder devices (and only in its own process)."""
import os

import jax
import numpy as np
import pytest

try:  # hypothesis is optional: fall back to a deterministic shim so the
    import hypothesis  # noqa: F401 — suite collects and runs without it
except ImportError:
    from _hypothesis_fallback import install as _install_hypothesis_fallback
    _install_hypothesis_fallback()

# property-test profiles: "ci" = more examples on a fixed seed (the CI
# hypothesis job), "dev" = the default budget.  Select via
# HYPOTHESIS_PROFILE; tests that hardcode max_examples keep their own
# budget (hypothesis semantics), so the long-running engine property
# tests read CHUNKED_PREFILL_EXAMPLES directly.
from hypothesis import settings as _hsettings

_hsettings.register_profile("ci", max_examples=25, deadline=None,
                            derandomize=True)
_hsettings.register_profile("dev", max_examples=10, deadline=None)
if os.environ.get("HYPOTHESIS_PROFILE"):
    _hsettings.load_profile(os.environ["HYPOTHESIS_PROFILE"])

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def toy_config(**over):
    from repro.models.config import ModelConfig
    base = dict(name="toy", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=257, dtype="float32", param_dtype="float32")
    base.update(over)
    return ModelConfig(**base)


@pytest.fixture
def dense_cfg():
    return toy_config()
