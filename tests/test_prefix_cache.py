"""Radix prefix cache: shared-prefix KV reuse over the paged arena.

Covers the arena's refcount lifecycle (share survives source eviction,
copy-on-write on divergence, LRU reclaim of cached-but-unreferenced
blocks first), the radix index (block-aligned chains, partial tails,
subtree eviction), engine-level reuse (identical greedy tokens with the
cache on vs off, hit/COW/eviction telemetry, cached-token queue-time
discount), the satellite knob validation, the ring one-shot-fallback
counter, the MoE expert-capacity drop counter and the simulator's
hit-rate-aware prefill cost.

``PREFIX_CACHE_EXAMPLES`` scales the property-test budget (the CI
hypothesis job raises it on a fixed seed)."""
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models import transformer as T
from repro.serving.arena import KVArena
from repro.serving.engine import GenerationRequest, ServiceRuntime
from repro.serving.prefix_cache import RadixPrefixCache

from conftest import toy_config

LAT = TaskCategory(Sensitivity.LATENCY, False)
FREQ = TaskCategory(Sensitivity.FREQUENCY, False)
_EXAMPLES = int(os.environ.get("PREFIX_CACHE_EXAMPLES", "6"))

_CFG = toy_config(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                  head_dim=16, d_ff=64)
_PARAMS = None


def _params():
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = T.init(jax.random.PRNGKey(7), _CFG)
    return _PARAMS


def _plan(bs=2, category=LAT, **kw):
    return ParallelPlan(service="t", category=category, bs=bs, **kw)


def _arena(capacity=3, max_seq_len=32, block_size=8, **kw):
    return KVArena(_CFG, T.init_cache, capacity=capacity,
                   max_seq_len=max_seq_len, block_size=block_size, **kw)


def _prefilled(arena, n_tokens, seed=0):
    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, _CFG.vocab_size, n_tokens).astype(np.int32)
    _, cache = T.prefill(_params(), _CFG, {"tokens": prompt[None]},
                         cache_size=arena.slot_tokens)
    return prompt, cache


# ---------------------------------------------------------------------------
# arena refcount lifecycle
# ---------------------------------------------------------------------------

def test_shared_block_survives_source_slot_eviction():
    """A prefix shared into a second slot must outlive the slot that
    wrote it: freeing the source only drops its reference."""
    a = _arena()
    prompt, cache = _prefilled(a, 16)               # 2 full blocks
    sA = a.alloc(24)
    a.write_prefill(sA, cache, prompt_len=16)
    rowA = a.block_tables()[sA][:2]
    want = np.asarray(
        a.dense_view(a.pages, a.block_tables()[sA][None])[0])[:, :, :16]
    sB = a.alloc(24, shared=list(rowA))
    assert all(a.block_ref(int(b)) == 2 for b in rowA)
    a.free(sA)                                      # source evicted
    assert all(a.block_ref(int(b)) == 1 for b in rowA)
    rowB = a.block_tables()[sB][:2]
    np.testing.assert_array_equal(rowB, rowA)       # stitched, not copied
    got = np.asarray(
        a.dense_view(a.pages, a.block_tables()[sB][None])[0])[:, :, :16]
    np.testing.assert_allclose(got, want)
    a.free(sB)                                      # last ref: blocks free
    assert len(a._free_blocks) == a.pool_blocks


def test_cow_on_divergence_isolates_writers():
    """cow_block forks a private copy: the sharer's writes land in its
    copy while the original block (still referenced elsewhere) is
    untouched."""
    import jax.numpy as jnp
    a = _arena()
    _, cache = _prefilled(a, 16)
    sA = a.alloc(24)
    a.write_prefill(sA, cache, prompt_len=16)
    rowA = a.block_tables()[sA][:2]
    sB = a.alloc(24, shared=list(rowA))
    assert a.cow_block(sB, 0)                       # shared -> must copy
    assert a.cow_copies == 1
    rowB = a.block_tables()[sB]
    assert rowB[0] != rowA[0] and rowB[1] == rowA[1]
    assert a.block_ref(int(rowA[0])) == 1           # back to A alone
    # the copy starts as an exact clone...
    rowA_full = a.block_tables()[sA][None]
    rowB_full = a.block_tables()[sB][None]
    va = np.asarray(a.dense_view(a.pages, rowA_full)[0])
    vb = np.asarray(a.dense_view(a.pages, rowB_full)[0])
    np.testing.assert_allclose(vb[:, :, :8], va[:, :, :8])
    # ...and diverging writes stay private to B
    dense_new = [jnp.ones((leaf.shape[0], 1, a.slot_tokens,
                           *leaf.shape[3:]), leaf.dtype)
                 for leaf in (cache["k"], cache["v"])]
    a.pages = a.append_rows(a.pages, dense_new, jnp.zeros((1,), jnp.int32),
                            jnp.ones((1,), bool), jnp.asarray(rowB_full))
    va2 = np.asarray(a.dense_view(a.pages, rowA_full)[0])
    np.testing.assert_allclose(va2, va)             # A unchanged
    # an exclusively owned, uncached block needs no copy
    assert not a.cow_block(sB, 0)


def test_lru_eviction_reclaims_cached_unreferenced_first():
    """Under pressure the allocator consumes the free list first, then
    idle-but-cached blocks in LRU order (firing the evict hook); blocks
    still referenced by live slots are never reclaimed."""
    a = _arena(capacity=3, max_seq_len=16, block_size=8)   # pool = 6
    evicted = []
    a.evict_hook = evicted.append
    s0 = a.alloc(16)
    first = list(a._slot_blocks[s0])
    for b in first:
        a.register(b)
    a.free(s0)                                      # -> idle cached (LRU)
    s1 = a.alloc(16)
    second = list(a._slot_blocks[s1])
    for b in second:
        a.register(b)
    a.free(s1)
    assert list(a._idle_cached) == first + second
    a.alloc(16)                  # 2 fresh blocks still on the free list
    assert evicted == [] and a.cached_evictions == 0
    a.alloc(16)                  # free list empty: reclaim LRU cached
    assert evicted == first      # oldest released first
    assert a.cached_evictions == 2
    hit_capable = set(a._idle_cached)
    assert hit_capable == set(second)               # MRU half survives


def test_retention_bound_caps_idle_cache():
    """The category knob: a bounded retention evicts LRU idle blocks as
    soon as the bound is exceeded, without allocator pressure."""
    a = _arena(capacity=3, max_seq_len=16, block_size=8)
    a.cache_retention = 2
    s0 = a.alloc(16)
    blocks = list(a._slot_blocks[s0])
    for b in blocks:
        a.register(b)
    s1 = a.alloc(16)
    more = list(a._slot_blocks[s1])
    for b in more:
        a.register(b)
    a.free(s0)
    assert len(a._idle_cached) == 2
    a.free(s1)                   # 4 idle > bound 2: evict 2 oldest
    assert len(a._idle_cached) == 2
    assert list(a._idle_cached) == more
    assert a.cached_evictions == 2


# ---------------------------------------------------------------------------
# radix index
# ---------------------------------------------------------------------------

def test_radix_lookup_full_blocks_partial_tail_and_cap():
    a = _arena(capacity=2, max_seq_len=48, block_size=8)
    pc = RadixPrefixCache(a)
    s0 = a.alloc(24)
    tokens = np.arange(1, 21, dtype=np.int32)        # 20: 2 full + 4 tail
    pc.insert(tokens, a.block_tables()[s0])
    row = a.block_tables()[s0]
    assert all(a.is_cached(int(b)) for b in row[:3])

    hit = pc.lookup(tokens)                          # identical prompt
    assert hit.tokens == 19                          # capped at len - 1
    assert hit.full_blocks == 2 and hit.partial_valid == 3
    assert hit.blocks == [int(row[0]), int(row[1]), int(row[2])]

    longer = np.concatenate([tokens, [77, 78]]).astype(np.int32)
    hit = pc.lookup(longer)                          # full partial usable
    assert hit.tokens == 20 and hit.partial_valid == 4

    fork = np.concatenate([tokens[:12], [99, 98, 97, 96]]).astype(np.int32)
    hit = pc.lookup(fork)                            # diverges mid-block 2
    assert hit.tokens == 8 and hit.full_blocks == 1
    assert hit.partial_valid == 0                    # no partials at depth 1

    assert pc.lookup(tokens[:5]).tokens == 0         # sub-block prompt


def test_radix_eviction_drops_subtree_and_frees_blocks():
    """Reclaiming a chain's root block must unregister its whole subtree
    (descendants are unreachable without the root) and return idle ones
    to the free list."""
    a = _arena(capacity=2, max_seq_len=48, block_size=8)
    pc = RadixPrefixCache(a)
    s0 = a.alloc(24)
    tokens = np.arange(1, 21, dtype=np.int32)
    pc.insert(tokens, a.block_tables()[s0])
    assert len(pc) == 3
    a.free(s0)                    # 3 idle cached, 9 on the free list
    a.alloc(48, slot=0)           # 6 blocks off the free list
    assert a.cached_evictions == 0
    a.alloc(48, slot=1)           # 3 free left: reclaim the cached chain
    assert a.cached_evictions >= 1
    assert len(pc) == 0           # root eviction dropped child + partial
    assert pc.lookup(tokens).tokens == 0


def test_insert_dedupes_onto_existing_chain():
    """Two identical prompts prefilled independently: the second insert
    reuses the first chain; its own blocks stay private and return to the
    free list on eviction."""
    a = _arena(capacity=2, max_seq_len=32, block_size=8)
    pc = RadixPrefixCache(a)
    tokens = np.arange(1, 17, dtype=np.int32)        # exactly 2 blocks
    s0, s1 = a.alloc(24), a.alloc(24)
    assert pc.insert(tokens, a.block_tables()[s0]) == 2
    assert pc.insert(tokens, a.block_tables()[s1]) == 0   # deduped
    hit = pc.lookup(np.concatenate([tokens, [5]]).astype(np.int32))
    assert hit.blocks == [int(b) for b in a.block_tables()[s0][:2]]
    a.free(s1)
    assert len(a._free_blocks) >= 3   # s1's blocks uncached -> free list


# ---------------------------------------------------------------------------
# engine-level reuse
# ---------------------------------------------------------------------------

def _serve(rt, reqs):
    for r in reqs:
        rt.submit(r)
    return {r.rid: tuple(r.tokens) for r in rt.drain()}


def _shared_prefix_reqs(rng, prefix, n, rid0=0, tail=6, max_new=3):
    reqs = []
    for i in range(n):
        t = rng.integers(1, _CFG.vocab_size, tail).astype(np.int32)
        reqs.append(GenerationRequest(
            rid=rid0 + i, tokens=np.concatenate([prefix, t]),
            max_new_tokens=max_new))
    return reqs


def test_repeated_prefix_identical_tokens_and_hit_telemetry():
    rng = np.random.default_rng(3)
    prefix = rng.integers(1, _CFG.vocab_size, 24).astype(np.int32)

    def run(**kw):
        rt = ServiceRuntime(_CFG, _params(), _plan(bs=2), max_seq_len=64,
                            block_size=8, **kw)
        r = np.random.default_rng(5)
        toks = _serve(rt, _shared_prefix_reqs(r, prefix, 1))      # warm
        toks.update(_serve(rt, _shared_prefix_reqs(r, prefix, 4, rid0=1)))
        return rt, toks

    rt_on, toks_on = run()
    rt_off, toks_off = run(prefix_cache=0)
    assert rt_on.prefix_cache_enabled and not rt_off.prefix_cache_enabled
    assert toks_on == toks_off
    assert rt_on.prefix_hits >= 3
    assert rt_on.prefix_hit_tokens >= 3 * 24
    assert rt_on.prefill_tokens_computed < rt_off.prefill_tokens_computed
    assert rt_off.prefix_hits == 0
    total = sum(24 + 6 for _ in range(5))
    assert rt_off.prefill_tokens_computed == total    # no silent reuse


def test_step_stats_report_prefix_counters():
    rng = np.random.default_rng(4)
    prefix = rng.integers(1, _CFG.vocab_size, 16).astype(np.int32)
    rt = ServiceRuntime(_CFG, _params(), _plan(bs=2), max_seq_len=64,
                        block_size=8)
    _serve(rt, _shared_prefix_reqs(rng, prefix, 1))    # warm + insert
    rt.submit(_shared_prefix_reqs(rng, prefix, 1, rid0=1)[0])
    stats = rt.step()
    assert stats.prefix_lookups == 1 and stats.prefix_hits == 1
    assert stats.prefix_hit_tokens >= 16
    assert stats.admitted == 1
    rt.drain()
    # cumulative counters stay consistent with per-step deltas
    assert rt.prefix_hits == 1 and rt.prefix_hit_tokens == stats.prefix_hit_tokens


def test_partial_tail_hit_triggers_cow_not_corruption():
    """Prompts diverging mid-block share the partial tail block and COW
    on first write; the warm prompt's later requests still hit its own
    chain and decode identically to a cache-off run."""
    rng = np.random.default_rng(9)
    base = rng.integers(1, _CFG.vocab_size, 20).astype(np.int32)  # 2.5 blk

    def run(**kw):
        rt = ServiceRuntime(_CFG, _params(), _plan(bs=2), max_seq_len=64,
                            block_size=8, **kw)
        toks = _serve(rt, [GenerationRequest(rid=0, tokens=base,
                                             max_new_tokens=3)])
        wave = [GenerationRequest(                     # same 18, fork at 19
            rid=1, tokens=np.concatenate([base[:18], [88, 87]])
            .astype(np.int32), max_new_tokens=3),
            GenerationRequest(rid=2, tokens=base.copy(), max_new_tokens=3)]
        toks.update(_serve(rt, wave))
        return rt, toks

    rt_on, toks_on = run()
    rt_off, toks_off = run(prefix_cache=0)
    assert toks_on == toks_off
    assert rt_on.prefix_cow_copies >= 1


def test_tight_pool_degrades_partial_share_without_failure():
    """When the pool cannot afford a partial-tail share's divergence-COW
    block, admission degrades to the full-block hit instead of raising
    mid-step — and tokens stay identical to a cache-off run."""
    rng = np.random.default_rng(2)
    base = rng.integers(1, _CFG.vocab_size, 20).astype(np.int32)
    blocker_prompt = rng.integers(1, _CFG.vocab_size, 16).astype(np.int32)
    member_prompt = np.concatenate([base[:19], [90]]).astype(np.int32)

    def run(knob):
        rt = ServiceRuntime(_CFG, _params(), _plan(bs=2), max_seq_len=48,
                            block_size=8, pool_blocks=6, prefix_cache=knob)
        toks = _serve(rt, [GenerationRequest(rid=0, tokens=base,
                                             max_new_tokens=2)])
        # blocker misses and pins the 3 remaining free blocks mid-decode
        rt.submit(GenerationRequest(rid=1, tokens=blocker_prompt,
                                    max_new_tokens=6))
        rt.step(); rt.step()
        # the member's partial-tail hit cannot afford its COW block now
        rt.submit(GenerationRequest(rid=2, tokens=member_prompt,
                                    max_new_tokens=2))
        stats = rt.step()
        toks.update({r.rid: tuple(r.tokens) for r in rt.drain()})
        return rt, toks, stats

    rt_on, toks_on, stats = run(6)   # retention = pool: never knob-evicted
    _, toks_off, _ = run(0)
    assert toks_on == toks_off and len(toks_on) == 3
    assert stats.admitted == 1
    assert stats.prefix_hit_tokens == 16     # degraded: 2 full blocks only
    assert stats.prefix_cow_blocks == 0      # ...so no divergence copy


def test_queue_time_estimate_discounts_cached_tokens():
    rt = ServiceRuntime(_CFG, _params(), _plan(bs=1), max_seq_len=64,
                        block_size=8)
    assert rt.prefix_cache_enabled
    rt._service_ewma_s = 1.0
    rt.submit(GenerationRequest(rid=0,
                                tokens=np.arange(1, 50, dtype=np.int32),
                                max_new_tokens=1))
    cold = rt.queue_time_estimate()
    rt._prefix_hit_ewma = 0.9
    warm = rt.queue_time_estimate()
    assert 0.0 < warm < cold


# ---------------------------------------------------------------------------
# property test: random share/COW/evict interleavings never corrupt
# another slot's decode output
# ---------------------------------------------------------------------------

@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2 ** 16), bs=st.integers(1, 3),
       retention=st.integers(1, 6))
def test_random_share_cow_evict_never_corrupts_neighbors(seed, bs,
                                                         retention):
    """Random admit schedules over prompts with shared, mid-block-diverging
    prefixes — under a tight retention bound that forces LRU eviction mid-
    flight — must produce byte-identical greedy tokens to a cache-off
    run for EVERY request."""
    rng = np.random.default_rng(seed)
    bases = [rng.integers(1, _CFG.vocab_size, 24).astype(np.int32)
             for _ in range(2)]
    reqs = []
    for i in range(6):
        base = bases[int(rng.integers(0, 2))]
        cut = int(rng.integers(4, 25))
        tail = rng.integers(1, _CFG.vocab_size,
                            int(rng.integers(0, 6))).astype(np.int32)
        prompt = np.concatenate([base[:cut], tail]).astype(np.int32)
        reqs.append((prompt, int(rng.integers(1, 5))))

    def run(knob):
        rt = ServiceRuntime(_CFG, _params(), _plan(bs=bs), max_seq_len=48,
                            block_size=8, prefix_cache=knob)
        for i, (p, n) in enumerate(reqs[:3]):
            rt.submit(GenerationRequest(rid=i, tokens=p, max_new_tokens=n))
        rt.step(); rt.step()                 # interleave mid-decode
        for i, (p, n) in enumerate(reqs[3:], start=3):
            rt.submit(GenerationRequest(rid=i, tokens=p, max_new_tokens=n))
        return {r.rid: tuple(r.tokens) for r in rt.drain()}

    assert run(retention) == run(0), (seed, bs, retention)


# ---------------------------------------------------------------------------
# satellites: knob validation, ring fallback counter, MoE drop counter,
# simulator hit-rate model
# ---------------------------------------------------------------------------

def test_parallel_plan_validates_knobs_at_construction():
    with pytest.raises(ValueError, match="prefill_chunk"):
        _plan(prefill_chunk=-8)
    with pytest.raises(ValueError, match="prefix_cache"):
        _plan(prefix_cache=-2)
    with pytest.raises(ValueError, match="bs"):
        ParallelPlan(service="t", category=LAT, bs=0)
    # category-derived retention: frequency keeps the pool, latency a
    # bounded fraction
    assert _plan(category=FREQ).prefix_cache_blocks(32) == 32
    assert _plan(category=LAT).prefix_cache_blocks(32) == 8
    assert _plan(prefix_cache=0).prefix_cache_blocks(32) == 0
    assert _plan(prefix_cache=5).prefix_cache_blocks(32) == 5


def test_engine_validates_chunk_and_prefix_knobs():
    with pytest.raises(ValueError, match="multiple of block_size"):
        ServiceRuntime(_CFG, _params(), _plan(), max_seq_len=64,
                       block_size=8, prefill_chunk=20)
    with pytest.raises(ValueError, match="multiple of block_size"):
        ServiceRuntime(_CFG, _params(), _plan(prefill_chunk=20),
                       max_seq_len=64, block_size=8)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServiceRuntime(_CFG, _params(), _plan(), max_seq_len=64,
                       block_size=8, prefix_cache=-5)
    # explicit prefix cache on a family whose KV is not a pure function
    # of prompt tokens must fail loudly
    ssm_cfg = toy_config(family="ssm", ssm_state=4, ssm_headdim=16)
    from repro.models import ssm as S
    with pytest.raises(ValueError, match="family"):
        ServiceRuntime(ssm_cfg, S.init(jax.random.PRNGKey(0), ssm_cfg),
                       _plan(), max_seq_len=64, block_size=8,
                       prefix_cache=True)


def test_ring_layout_falls_back_to_oneshot_with_counter():
    """Sliding-window (ring) layouts cannot take chunked prefill; the
    fallback is an explicit engine state plus a StepStats counter instead
    of a silent slow path."""
    cfg = toy_config(sliding_window=16)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rt = ServiceRuntime(cfg, params, _plan(bs=2), max_seq_len=64,
                        block_size=8)
    assert not rt.chunked_prefill and rt.ring_fallback
    assert not rt.prefix_cache_enabled          # needs chunked prefill
    rt.submit(GenerationRequest(rid=0, tokens=np.arange(1, 9,
                                                        dtype=np.int32),
                                max_new_tokens=2))
    stats = rt.step()
    assert stats.oneshot_prefills == 1
    rt.drain()
    assert rt.oneshot_prefills == 1
    # non-ring chunked configs never take the one-shot path
    rt2 = ServiceRuntime(_CFG, _params(), _plan(bs=2), max_seq_len=64,
                         block_size=8)
    rt2.submit(GenerationRequest(rid=0, tokens=np.arange(1, 9,
                                                         dtype=np.int32),
                                 max_new_tokens=2))
    rt2.drain()
    assert rt2.oneshot_prefills == 0 and not rt2.ring_fallback


def test_moe_capacity_drop_counter_observes_binding_capacity():
    from repro.models import moe as M
    cfg = toy_config(family="moe", num_experts=4, experts_per_token=2,
                     moe_capacity_factor=0.25)       # binding capacity
    params = M.init(jax.random.PRNGKey(0), cfg)
    rt = ServiceRuntime(cfg, params, _plan(bs=2), max_seq_len=48,
                        block_size=8)
    assert rt._moe_stats is M.MOE_DROP_STATS
    d0 = M.MOE_DROP_STATS.dropped
    rng = np.random.default_rng(0)
    dropped = 0.0
    rt.submit(GenerationRequest(
        rid=0, tokens=rng.integers(1, cfg.vocab_size, 12).astype(np.int32),
        max_new_tokens=2))
    while rt.pending() or rt.in_flight():
        dropped += rt.step().moe_dropped_tokens
    assert M.MOE_DROP_STATS.dropped > d0             # drops observed
    assert dropped > 0.0                             # ...and attributed
    assert 0.0 < M.MOE_DROP_STATS.drop_rate <= 1.0


def test_simulator_prefix_hit_rate_prices_reuse():
    import dataclasses as dc

    from repro.core.categories import Request, ServerSpec, ServiceSpec
    from repro.simulator.engine import SimConfig, run_comparison

    servers = [ServerSpec(sid=0, num_gpus=2)]
    services = {"chat": ServiceSpec("chat", flops_per_request=5e9,
                                    weights_bytes=1e8, vram_bytes=3e8,
                                    slo_latency_s=0.4)}
    rng = np.random.default_rng(0)
    events, t = [], 0.0
    for i in range(50):
        t += float(rng.exponential(0.05))
        events.append((t, 0, Request(rid=i, service="chat", arrival_s=t,
                                     deadline_s=t + 0.4,
                                     prompt_tokens=400)))
    base = SimConfig(horizon_s=10.0, sync_interval_s=1.0,
                     prefill_token_s=2e-4, prefill_chunk_tokens=64)
    cold = run_comparison(servers, services, events, ["EPARA"],
                          base)["EPARA"]
    warm = run_comparison(servers, services, events, ["EPARA"],
                          dc.replace(base, prefix_hit_rate=0.75))["EPARA"]
    assert warm.cached_prefill_s > 0.0 and cold.cached_prefill_s == 0.0
    assert warm.goodput >= cold.goodput
    # services the live engine cannot cache (SSM state, enc-dec/VLM
    # embedding-dependent KV) never get the discount
    uncached = {"chat": dc.replace(services["chat"],
                                   prefix_cacheable=False)}
    gated = run_comparison(servers, uncached, events, ["EPARA"],
                           dc.replace(base, prefix_hit_rate=0.75))["EPARA"]
    assert gated.cached_prefill_s == 0.0
    with pytest.raises(ValueError, match="prefix_hit_rate"):
        run_comparison(servers, services, events, ["EPARA"],
                       dc.replace(base, prefix_hit_rate=1.5))


def test_derived_prefix_hit_rates_follow_template_structure():
    """The simulator's hit-rate input comes from the generated trace's
    ACTUAL template-repeat structure, not a hand-tuned constant: first
    use of a (service, server, template) misses, repeats hit the shared
    prefix; no templates -> zero everywhere; frequency services (no
    prompt modeling) never appear."""
    import dataclasses as dc

    from repro.simulator.workload import (WorkloadConfig,
                                          derive_prefix_hit_rates,
                                          generate_requests,
                                          table1_services)

    services = table1_services(include_heavy=False)
    cfg = WorkloadConfig(horizon_s=30.0, load_scale=10.0, seed=3,
                         prompt_tokens=400, template_tokens=300,
                         template_repeat_p=0.8)
    events = generate_requests(services, 2, cfg)
    rates = derive_prefix_hit_rates(events, services, cfg)
    lat = {n for n, s in services.items() if not s.is_frequency}
    assert rates and set(rates) <= lat
    assert all(0.0 <= r < 1.0 for r in rates.values())
    assert max(rates.values()) > 0.0                 # repeats observed
    # rates are bounded by the template share of the prompt x repeat mass
    assert all(r <= cfg.template_tokens / cfg.prompt_tokens
               for r in rates.values())
    # one-off prompts only -> derived reuse is zero for every service
    cold = dc.replace(cfg, template_repeat_p=0.0)
    rates0 = derive_prefix_hit_rates(
        generate_requests(services, 2, cold), services, cold)
    assert rates0 and all(r == 0.0 for r in rates0.values())
    # heavier repeat probability -> no service's derived rate decreases
    # in aggregate (same arrival process, more template mass)
    hot = dc.replace(cfg, template_repeat_p=1.0)
    rates1 = derive_prefix_hit_rates(
        generate_requests(services, 2, hot), services, hot)
    assert sum(rates1.values()) >= sum(rates.values())


def test_simulator_per_service_hit_rates_override_scalar():
    """``SimConfig.prefix_hit_rates`` prices reuse per service: a mapped
    service takes its derived rate, an absent one falls back to the
    scalar; out-of-range per-service rates are rejected at
    construction."""
    import dataclasses as dc

    from repro.core.categories import Request, ServerSpec, ServiceSpec
    from repro.simulator.engine import SimConfig, run_comparison

    servers = [ServerSpec(sid=0, num_gpus=2)]
    services = {"chat": ServiceSpec("chat", flops_per_request=5e9,
                                    weights_bytes=1e8, vram_bytes=3e8,
                                    slo_latency_s=0.4)}
    rng = np.random.default_rng(0)
    events, t = [], 0.0
    for i in range(50):
        t += float(rng.exponential(0.05))
        events.append((t, 0, Request(rid=i, service="chat", arrival_s=t,
                                     deadline_s=t + 0.4,
                                     prompt_tokens=400)))
    base = SimConfig(horizon_s=10.0, sync_interval_s=1.0,
                     prefill_token_s=2e-4, prefill_chunk_tokens=64)
    mapped = run_comparison(
        servers, services, events, ["EPARA"],
        dc.replace(base, prefix_hit_rates={"chat": 0.75}))["EPARA"]
    assert mapped.cached_prefill_s > 0.0
    # absent from the map -> the scalar (here 0.0) applies
    other = run_comparison(
        servers, services, events, ["EPARA"],
        dc.replace(base, prefix_hit_rates={"not-chat": 0.75}))["EPARA"]
    assert other.cached_prefill_s == 0.0
    with pytest.raises(ValueError, match="prefix_hit_rates"):
        run_comparison(servers, services, events, ["EPARA"],
                       dc.replace(base, prefix_hit_rates={"chat": 1.5}))
