"""Fault tolerance (core/faults.py + serving/failover.py): deterministic
replayable fault schedules, crash evacuation invariants on the slot
engine, supervisor recovery (crash mid-burst, dropped handoffs, retry
budget exhaustion), degraded-mode routing, the simulator's failure
processes, and the property that random fault schedules never corrupt a
surviving request's greedy tokens or leave a rid unaccounted.

``FAULTS_EXAMPLES`` scales the hypothesis example budget in CI.
"""
import functools
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (EdgeCloudControlPlane, Outcome, ServerSpec,
                        ServiceSpec)
from repro.core.categories import REJECT_VERDICTS
from repro.core.faults import (FAULT_KINDS, FaultEvent, FaultInjector,
                               FaultSpec, random_fault_spec)
from repro.core.handler import RequestHandler, ServerView, ServiceState
from repro.models import transformer as T
from repro.serving.engine import (EparaServingEngine, GenerationRequest,
                                  ServiceRuntime)
from repro.serving.failover import ClusterSupervisor, RetryPolicy

from conftest import toy_config

_EXAMPLES = int(os.environ.get("FAULTS_EXAMPLES", "3"))


@functools.lru_cache(maxsize=1)
def _toy():
    cfg = toy_config()
    return cfg, T.init(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def toy():
    return _toy()


def _req(rid, prompt=6, max_new=4, stream=None, deadline=0.0):
    return GenerationRequest(
        rid=rid, tokens=np.arange(1, 1 + prompt, dtype=np.int32),
        max_new_tokens=max_new, deadline_s=deadline,
        stream=rid if stream is None else stream)


def _cluster(cfg, params, n_servers=3, **cp_kw):
    """Toy control plane + one 'chat' service deployed on every server."""
    specs = {"chat": ServiceSpec("chat", flops_per_request=1e10,
                                 weights_bytes=2e8, vram_bytes=5e8,
                                 slo_latency_s=100.0)}
    servers = [ServerSpec(sid=i, num_gpus=2) for i in range(n_servers)]
    cp = EdgeCloudControlPlane(servers, specs, **cp_kw)
    cp.run_placement({("chat", i): 10.0 for i in range(n_servers)})
    engines = {s.sid: EparaServingEngine() for s in servers}
    for svc, sid in cp.placements:
        if sid >= 0 and svc not in engines[sid].runtimes:
            engines[sid].deploy(svc, ServiceRuntime(cfg, params,
                                                    cp.plans[svc]))
    # make sure every server hosts the service (crash tests need
    # survivors with capacity)
    for sid in engines:
        if "chat" not in engines[sid].runtimes:
            engines[sid].deploy("chat", ServiceRuntime(cfg, params,
                                                       cp.plans["chat"]))
    cp.publish_all(0.0)
    for _ in range(n_servers):
        cp.sync_step(0.0)
    return cp, engines


# ---------------------------------------------------------------------------
# FaultSpec / FaultInjector: pure-data determinism
# ---------------------------------------------------------------------------

def test_fault_event_kind_validated():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(at_s=1.0, kind="meteor", sid=0)
    for kind in FAULT_KINDS:
        FaultEvent(at_s=1.0, kind=kind, sid=0)


def test_fault_spec_sorted_and_json_roundtrip():
    spec = FaultSpec(events=(
        FaultEvent(at_s=9.0, kind="restart", sid=1),
        FaultEvent(at_s=2.0, kind="crash", sid=1),
        FaultEvent(at_s=5.0, kind="corrupt", sid=0, factor=3.0)), seed=7)
    assert [e.at_s for e in spec.events] == [2.0, 5.0, 9.0]
    again = FaultSpec.from_json(spec.to_json())
    assert again == spec
    assert again.crashed_servers() == (1,)
    assert [e.kind for e in again.for_server(1)] == ["crash", "restart"]


def test_random_fault_spec_deterministic_and_bounded():
    a = random_fault_spec([0, 1, 2], 20.0, seed=3, crashes=2)
    b = random_fault_spec([0, 1, 2], 20.0, seed=3, crashes=2)
    c = random_fault_spec([0, 1, 2], 20.0, seed=4, crashes=2)
    assert a == b
    assert a != c
    # min_alive: never more than len(ids) - 1 distinct crash victims,
    # and every crash has a paired restart inside the horizon
    assert len(a.crashed_servers()) <= 2
    crashes = [e for e in a.events if e.kind == "crash"]
    restarts = [e for e in a.events if e.kind == "restart"]
    assert len(crashes) == len(restarts)
    assert all(e.at_s <= 20.0 for e in a.events)
    with pytest.raises(ValueError, match="min_alive"):
        random_fault_spec([0, 1], 10.0, min_alive=0)


def test_injector_replays_in_schedule_order():
    spec = random_fault_spec([0, 1, 2], 10.0, seed=1, crashes=1,
                             stragglers=2, corruptions=1,
                             dropped_offloads=1)

    class Recorder:
        def __init__(self):
            self.calls = []

        def __getattr__(self, kind):
            return lambda ev, now: self.calls.append((ev.kind, ev.sid))

    runs = []
    for _ in range(2):
        inj, rec = FaultInjector(spec), Recorder()
        assert inj.next_at() == spec.events[0].at_s
        t = 0.0
        while inj.pending:
            t = inj.next_at()
            inj.drive(t, rec)
        runs.append(rec.calls)
    assert runs[0] == runs[1]
    assert len(runs[0]) == len(spec.events)
    assert [k for k, _ in runs[0]] == [e.kind for e in spec.events]


# ---------------------------------------------------------------------------
# handler: staleness-bound exclusion (degraded mode)
# ---------------------------------------------------------------------------

def test_stale_peer_excluded_not_attractive():
    """A silently dead peer's frozen digest advertises pre-crash idle
    goodput; past the staleness bound the handler must exclude it rather
    than score it (the stale-peer-attraction bug)."""
    h = RequestHandler(0, staleness_bound_s=5.0)
    svc = ServiceSpec("chat", flops_per_request=1e9, weights_bytes=1e8,
                      vram_bytes=1e8, slo_latency_s=1000.0)
    from repro.core.categories import Request
    req = Request(rid=1, service="chat", arrival_s=0.0, deadline_s=1e9)
    fresh = ServerView(sid=1, sync_age_s=1.0, services={
        "chat": ServiceState(theoretical_goodput=1.0)})
    stale = ServerView(sid=2, sync_age_s=50.0, services={
        "chat": ServiceState(theoretical_goodput=1000.0)})
    local = ServerView(sid=0, services={})      # nothing local -> offload
    for _ in range(20):
        d = h.handle(req, 0.0, svc, local, {1: fresh, 2: stale})
        assert d.outcome == Outcome.OFFLOAD
        assert d.destination == 1, "stale peer attracted traffic"
    # with no bound the stale giant wins almost always — the bug existed
    h2 = RequestHandler(0)
    got2 = {h2.handle(req, 0.0, svc, local, {1: fresh, 2: stale})
            .destination for _ in range(20)}
    assert 2 in got2


def test_handler_staleness_bound_validated():
    with pytest.raises(ValueError, match="staleness_bound_s"):
        RequestHandler(0, staleness_bound_s=0.0)


def test_control_plane_degrades_failed_server(toy):
    cfg, params = toy
    cp, engines = _cluster(cfg, params)
    from repro.core.categories import Request
    req = Request(rid=1, service="chat", arrival_s=0.0, deadline_s=1e9)
    assert cp.handle(req, now=1.0, at_server=0).outcome in (
        Outcome.LOCAL, Outcome.LOCAL_CROSS)
    cp.fail_server(0, 1.0)
    assert 0 in cp.failed_servers
    # a request originating AT the corpse can only offload
    d = cp.handle(req, now=1.5, at_server=0)
    assert d.outcome == Outcome.OFFLOAD
    assert d.destination != 0
    # peers stop seeing it as available
    views = cp.sync.views_for(1, 1.5)
    assert not views[0].available
    cp.repair_server(0, 2.0)
    assert 0 not in cp.failed_servers


# ---------------------------------------------------------------------------
# engine: crash evacuation invariants
# ---------------------------------------------------------------------------

def test_evacuate_strips_queued_and_inflight(toy):
    cfg, params = toy
    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    plan = ParallelPlan(service="t",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=2)
    rt = ServiceRuntime(cfg, params, plan)
    for i in range(5):
        rt.submit(_req(i, max_new=6), now=0.0)
    rt.step(now=1.0)                 # two in flight, three queued
    assert rt.in_flight() and rt.pending()
    reqs = rt.evacuate(now=2.0)
    assert sorted(r.rid for r in reqs) == [0, 1, 2, 3, 4]
    assert rt.pending() == 0 and rt.in_flight() == 0
    assert rt.evacuations == 1 and rt.evacuated_requests == 5
    for g in rt.groups.values():
        if g.arena is not None:
            assert g.arena.live == 0 and g.arena.parked_blocks == 0
    # the delta surfaces once through StepStats
    stats = rt.step(now=3.0)
    assert stats.evacuated == 5
    assert rt.step(now=4.0).evacuated == 0
    # the runtime still serves after evacuation (resubmission target)
    rt.submit(_req(100), now=5.0)
    out = rt.drain(now=5.0)
    assert [r.rid for r in out] == [100]


def test_evacuate_releases_parked_blocks(toy):
    cfg, params = toy
    from repro.core.allocator import ParallelPlan
    from repro.core.categories import Sensitivity, TaskCategory
    plan = ParallelPlan(service="t",
                        category=TaskCategory(Sensitivity.LATENCY, False),
                        bs=2, admission="sdf")
    rt = ServiceRuntime(cfg, params, plan)
    # seed EWMAs so the controller preempts
    rt.submit(_req(999), now=0.0)
    t = 0.0
    while rt.pending() or rt.in_flight():
        rt.step(now=t)
        t += 1.0
    for i in range(2):
        rt.submit(_req(i, max_new=8), now=t)
    rt.step(now=t)
    rt.submit(_req(7, max_new=2, deadline=t + 3.0), now=t)
    for _ in range(3):               # give the preemption a chance
        rt.step(now=t)
        t += 0.5
    reqs = rt.evacuate(now=t)
    rids = {r.rid for r in reqs}
    assert rids and rids <= {0, 1, 7}
    assert not rt.admission.parked
    for g in rt.groups.values():
        if g.arena is not None:
            assert g.arena.live == 0 and g.arena.parked_blocks == 0


# ---------------------------------------------------------------------------
# supervisor: recovery end to end
# ---------------------------------------------------------------------------

def _run_supervised(cfg, params, n_requests, injector=None,
                    retry=None, n_servers=3):
    cp, engines = _cluster(cfg, params, n_servers=n_servers)
    sup = ClusterSupervisor(cp, engines, injector=injector,
                            retry=retry or RetryPolicy(base_timeout_s=4.0))
    for i in range(n_requests):
        sup.submit("chat", _req(i), at_server=i % n_servers, now=0.0)
    return sup, sup.run_until_idle()


def test_crash_midburst_served_or_verdicted_bit_identical(toy):
    cfg, params = toy
    inj = FaultInjector(FaultSpec(events=(
        FaultEvent(at_s=2.0, kind="crash", sid=0),
        FaultEvent(at_s=8.0, kind="restart", sid=0))))
    n = 12
    sup, rep = _run_supervised(cfg, params, n, injector=inj)
    assert rep.accounted == n, "silently lost requests"
    assert rep.evacuated > 0 and rep.failovers > 0
    assert 0 not in sup.down       # restarted and rejoined
    # bit-identity vs the failure-free oracle on served intersection
    _, oracle = _run_supervised(cfg, params, n)
    assert oracle.accounted == n and not oracle.rejects
    want = {r.rid: list(map(int, r.tokens))
            for r in oracle.results if r.sample == 0}
    got = {r.rid: list(map(int, r.tokens))
           for r in rep.results if r.sample == 0}
    for rid in set(want) & set(got):
        assert got[rid] == want[rid], f"rid {rid} corrupted by failover"


def test_dropped_offload_recovered_by_timeout_retry(toy):
    cfg, params = toy
    inj = FaultInjector(FaultSpec(events=(
        FaultEvent(at_s=0.5, kind="drop_offload", sid=1, count=2),)))
    cp, engines = _cluster(cfg, params)
    sup = ClusterSupervisor(cp, engines, injector=inj,
                            retry=RetryPolicy(base_timeout_s=2.0))
    sup.step(1.0)                    # arm the drop budget first
    for i in range(4):
        sup.submit("chat", _req(i), at_server=1, now=1.0)
    rep = sup.run_until_idle(now=1.0)
    assert rep.accounted == 4
    assert rep.dropped_offloads == 2
    assert rep.offload_retries >= 2  # the timeouts recovered them
    assert not rep.rejects


def test_failed_verdict_when_no_host_left(toy):
    cfg, params = toy
    inj = FaultInjector(FaultSpec(events=tuple(
        FaultEvent(at_s=1.5, kind="crash", sid=s) for s in range(3))))
    sup, rep = _run_supervised(cfg, params, 6, injector=inj)
    assert rep.accounted == 6
    assert rep.rejects, "total cluster loss must verdict, not hang"
    assert all(r.verdict is Outcome.FAILED for r in rep.rejects)
    assert all(r.attempts >= 1 for r in rep.rejects)
    assert Outcome.FAILED in REJECT_VERDICTS


def test_retry_policy_backoff_and_deadline_cap():
    p = RetryPolicy(base_timeout_s=2.0, backoff=3.0, max_attempts=5,
                    deadline_fraction=0.5)
    assert p.timeout_s(0, 0.0, 0.0) == pytest.approx(2.0)
    assert p.timeout_s(2, 0.0, 0.0) == pytest.approx(18.0)
    # deadline caps the wait at half the remaining slack...
    assert p.timeout_s(3, 20.0, 10.0) == pytest.approx(5.0)
    # ...but never below one base timeout
    assert p.timeout_s(3, 10.5, 10.0) == pytest.approx(2.0)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff=0.5)


def test_straggler_skips_rounds_but_serves(toy):
    cfg, params = toy
    inj = FaultInjector(FaultSpec(events=(
        FaultEvent(at_s=0.5, kind="straggle", sid=0, duration_s=6.0,
                   factor=3.0),)))
    sup, rep = _run_supervised(cfg, params, 9, injector=inj)
    assert rep.accounted == 9
    assert rep.heartbeat_misses > 0


# ---------------------------------------------------------------------------
# simulator failure processes
# ---------------------------------------------------------------------------

def test_simulator_faults_deterministic_and_accounted():
    from repro.core.categories import EDGE_P100
    from repro.simulator.baselines import make_scheduler
    from repro.simulator.engine import SimConfig, Simulation
    from repro.simulator.workload import (WorkloadConfig, generate_requests,
                                          table1_services)
    services = table1_services()
    servers = [ServerSpec(sid=i, num_gpus=1, gpu=EDGE_P100)
               for i in range(4)]
    wl = WorkloadConfig(horizon_s=30.0, load_scale=20.0, seed=3)
    events = generate_requests(services, len(servers), wl)
    spec = FaultSpec(events=(
        FaultEvent(at_s=8.0, kind="crash", sid=1),
        FaultEvent(at_s=16.0, kind="restart", sid=1),
        FaultEvent(at_s=5.0, kind="drop_offload", sid=2, count=3),
        FaultEvent(at_s=10.0, kind="straggle", sid=3, duration_s=5.0,
                   factor=4.0)))

    def run(fault_spec):
        return Simulation(
            servers, services,
            make_scheduler("EPARA", services, EDGE_P100, seed=1),
            events, SimConfig(horizon_s=30.0, fault_spec=fault_spec)).run()

    base = run(None)
    a, b = run(spec), run(spec)
    assert a.goodput == pytest.approx(b.goodput)
    assert a.verdicts == b.verdicts
    assert a.crashes == 1
    assert a.dropped_offloads == 3
    assert a.failover_resubmits >= a.dropped_offloads
    assert a.goodput < base.goodput          # faults cost goodput
    assert a.goodput > 0.5 * base.goodput    # but recovery keeps most


# ---------------------------------------------------------------------------
# property: random fault schedules never corrupt survivors or lose rids
# ---------------------------------------------------------------------------

@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(chaos_seed=st.integers(min_value=0, max_value=10**6),
       n_requests=st.integers(min_value=4, max_value=10),
       crashes=st.integers(min_value=0, max_value=2),
       drops=st.integers(min_value=0, max_value=2))
def test_random_fault_schedules_preserve_survivors(chaos_seed, n_requests,
                                                   crashes, drops):
    """For ANY seed-generated fault schedule against a bursty toy
    cluster: (a) every rid ends served-or-verdicted; (b) each served
    request's greedy tokens are bit-identical to the failure-free
    oracle's (the intersection check — crashes must never corrupt
    survivors)."""
    cfg, params = _toy()
    spec = random_fault_spec([0, 1, 2], 12.0, seed=chaos_seed,
                             crashes=crashes, stragglers=1, corruptions=1,
                             dropped_offloads=drops, min_alive=1)
    sup, rep = _run_supervised(cfg, params, n_requests,
                               injector=FaultInjector(spec),
                               retry=RetryPolicy(base_timeout_s=3.0))
    assert rep.accounted == n_requests, \
        f"unaccounted rids under {spec.to_json()}"
    assert all(r.verdict in REJECT_VERDICTS for r in rep.rejects)
    _, oracle = _run_supervised(cfg, params, n_requests)
    want = {r.rid: list(map(int, r.tokens))
            for r in oracle.results if r.sample == 0}
    got = {r.rid: list(map(int, r.tokens))
           for r in rep.results if r.sample == 0}
    for rid in set(want) & set(got):
        assert got[rid] == want[rid], \
            f"rid {rid} corrupted under {spec.to_json()}"
