"""Speculative + n>1 parallel decoding, and the batch-composition
sampling bugfix they are built on.

The headline regression: a request's sampled tokens must be a pure
function of (seed, sample_idx, emitted offset) — bit-identical whether it
decodes alone, inside a full batch, or across a park/resume cycle.  The
old engine split one batch-wide key per fused step, so admitting an
unrelated request changed another request's output.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import toy_config
from repro.core.allocator import ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models import transformer as T
from repro.models.registry import model_api
from repro.serving.arena import KVArena
from repro.serving.engine import GenerationRequest, ServiceRuntime
from repro.serving.sampler import (STREAM_DECODE, STREAM_DRAFT,
                                   SamplerConfig, sample_per_slot,
                                   slot_keys, speculative_verify)

LAT = TaskCategory(Sensitivity.LATENCY, False)
FREQ = TaskCategory(Sensitivity.FREQUENCY, False)
STOCH = SamplerConfig(temperature=0.8, top_k=40)


@pytest.fixture(scope="module")
def toy():
    cfg = toy_config()
    return cfg, T.init(jax.random.PRNGKey(0), cfg)


def _runtime(toy, *, sampler=STOCH, category=LAT, bs=4, **kw):
    cfg, params = toy
    plan = ParallelPlan(service="toy", category=category, bs=bs)
    return ServiceRuntime(cfg, params, plan, sampler=sampler, **kw)


def _tokens_of(rt, reqs):
    for r in reqs:
        rt.submit(r)
    return {(r.rid, r.sample): list(map(int, r.tokens))
            for r in rt.drain()}


# ---------------------------------------------------------------------
# headline bugfix: batch-composition-independent sampling
# ---------------------------------------------------------------------
def test_sampling_independent_of_batch_composition(toy):
    """rid=1's stochastic tokens are bit-identical alone and sharing the
    batch with unrelated traffic — the regression the per-slot counter
    streams fix."""
    prompt = np.arange(1, 8, dtype=np.int32)
    alone = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=1, tokens=prompt, max_new_tokens=6)])
    mixed = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=1, tokens=prompt.copy(), max_new_tokens=6),
        GenerationRequest(rid=2, tokens=np.arange(3, 12, dtype=np.int32),
                          max_new_tokens=9),
        GenerationRequest(rid=3, tokens=np.arange(5, 9, dtype=np.int32),
                          max_new_tokens=4)])
    assert alone[(1, 0)] == mixed[(1, 0)]


def test_sampling_independent_of_arrival_order(toy):
    """Same two requests, swapped submission order: each keeps its own
    stream (the old batch-wide split keyed on step count, so order
    mattered)."""
    a = GenerationRequest(rid=1, tokens=np.arange(1, 8, dtype=np.int32),
                          max_new_tokens=5)
    b = GenerationRequest(rid=2, tokens=np.arange(2, 9, dtype=np.int32),
                          max_new_tokens=5)
    ab = _tokens_of(_runtime(toy), [a, b])
    ba = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=2, tokens=np.arange(2, 9, dtype=np.int32),
                          max_new_tokens=5),
        GenerationRequest(rid=1, tokens=np.arange(1, 8, dtype=np.int32),
                          max_new_tokens=5)])
    assert ab == ba


def test_sampling_survives_park_resume(toy):
    """Preempting a slot mid-decode (block-table parking) and resuming it
    must not shift its sample stream: the counter streams key on emitted
    offset, not on how many fused steps the engine ran in between."""
    prompt = np.arange(1, 8, dtype=np.int32)
    want = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=5, tokens=prompt, max_new_tokens=8)])

    rt = _runtime(toy)
    rt.submit(GenerationRequest(rid=5, tokens=prompt.copy(),
                                max_new_tokens=8))
    # step until mid-decode, then park the slot by hand (the admission
    # controller's preemption path uses exactly this helper)
    for _ in range(16):
        rt.step()
        state = rt.groups[0]
        if state.slots and not state.slots[0].prefilling \
                and len(state.slots[0].emitted) >= 3:
            break
    state = rt.groups[0]
    assert state.slots and len(state.slots[0].emitted) >= 3
    rt._park_slot(0, state, state.slots[0], now=0.0)
    assert rt.admission.parked
    got = {(r.rid, r.sample): list(map(int, r.tokens)) for r in rt.drain()}
    assert got == want


def test_explicit_seed_decouples_stream_from_rid(toy):
    """Two different rids pinned to the same seed draw the same stream;
    the same rid under different seeds draws different ones."""
    prompt = np.arange(1, 8, dtype=np.int32)
    r1 = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=1, tokens=prompt, max_new_tokens=6, seed=77)])
    r2 = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=2, tokens=prompt.copy(), max_new_tokens=6,
                          seed=77)])
    r3 = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=1, tokens=prompt.copy(), max_new_tokens=6,
                          seed=78)])
    assert r1[(1, 0)] == r2[(2, 0)]
    assert r1[(1, 0)] != r3[(1, 0)]


def test_sync_and_continuous_streams_match(toy):
    """The same counter chain drives both serving modes, so stochastic
    tokens now agree across them too (greedy always did)."""
    prompt = np.arange(1, 8, dtype=np.int32)
    cont = _tokens_of(_runtime(toy), [
        GenerationRequest(rid=3, tokens=prompt, max_new_tokens=5)])
    sync = _tokens_of(_runtime(toy, mode="sync"), [
        GenerationRequest(rid=3, tokens=prompt.copy(), max_new_tokens=5)])
    assert cont[(3, 0)] == sync[(3, 0)]


# ---------------------------------------------------------------------
# non-greedy sampler semantics (satellite: masks, fill, ties)
# ---------------------------------------------------------------------
def test_sample_per_slot_masks_and_fill_token():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16)),
                         jnp.float32)
    base = jax.random.PRNGKey(0)
    cfg = SamplerConfig(temperature=0.8, top_k=8)
    out = sample_per_slot(
        logits, base, [1, 2, 3, 4], [0] * 4, [0] * 4, cfg,
        live=jnp.asarray([True, False, True, True]),
        occupancy=jnp.asarray([True, True, False, True]), fill_token=9)
    out = np.asarray(out)
    assert out[1] == 9 and out[2] == 9          # masked rows filled
    assert out[0] != 9 or out[3] != 9           # real rows sampled
    # greedy ignores keys but still masks
    g = np.asarray(sample_per_slot(
        logits, base, [0] * 4, [0] * 4, [0] * 4, SamplerConfig(),
        live=jnp.asarray([False, True, True, True]), fill_token=7))
    assert g[0] == 7
    assert (g[1:] == np.argmax(np.asarray(logits), -1)[1:]).all()


def test_sample_per_slot_rows_are_independent():
    """Row i's draw depends only on its own (seed, sample, offset) chain,
    not on what else is in the batch."""
    rng = np.random.default_rng(1)
    row = rng.normal(size=(16,)).astype(np.float32)
    other = rng.normal(size=(3, 16)).astype(np.float32)
    base = jax.random.PRNGKey(0)
    cfg = SamplerConfig(temperature=0.8, top_k=8)
    alone = np.asarray(sample_per_slot(
        jnp.asarray(row[None]), base, [5], [0], [3], cfg))[0]
    batched = np.asarray(sample_per_slot(
        jnp.asarray(np.vstack([other, row[None]])), base,
        [1, 2, 3, 5], [0] * 4, [9, 1, 4, 3], cfg))[3]
    assert alone == batched


def test_top_k_tie_at_cutoff_is_deterministic():
    """Ties AT the top_k cutoff are kept (not arbitrarily dropped), and
    the same key resolves them identically every run."""
    logits = np.full((1, 8), -5.0, np.float32)
    logits[0, [1, 4, 6]] = 2.0                   # three-way tie, top_k=2
    cfg = SamplerConfig(temperature=1.0, top_k=2)
    base = jax.random.PRNGKey(0)
    draws = {int(np.asarray(sample_per_slot(
        jnp.asarray(logits), base, [s], [0], [0], cfg))[0])
        for s in range(40)}
    assert draws <= {1, 4, 6}                    # never below the cutoff
    a = sample_per_slot(jnp.asarray(logits), base, [7], [0], [0], cfg)
    b = sample_per_slot(jnp.asarray(logits), base, [7], [0], [0], cfg)
    assert int(np.asarray(a)[0]) == int(np.asarray(b)[0])


def test_stream_tags_are_disjoint():
    """The decode and draft streams of one request never collide — the
    fourth fold_in separates consumers."""
    base = jax.random.PRNGKey(0)
    kd = np.asarray(slot_keys(base, [1], [0], [0], STREAM_DECODE))
    kf = np.asarray(slot_keys(base, [1], [0], [0], STREAM_DRAFT))
    assert (kd != kf).any()


@settings(deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 4))
def test_greedy_verify_matches_argmax_prefix(seed, k):
    """Property: greedy speculative_verify emits exactly the target's
    argmax sequence for as long as the drafts agree, then one more."""
    rng = np.random.default_rng(seed)
    tl = rng.normal(size=(2, k + 1, 13)).astype(np.float32)
    targets = np.argmax(tl, -1)
    drafts = targets[:, :k].copy()
    if k >= 2:
        drafts[0, k - 1] = (drafts[0, k - 1] + 1) % 13   # force a reject
    out, n_emit = speculative_verify(
        jnp.asarray(tl), jnp.zeros((2, k, 13), jnp.float32),
        jnp.asarray(drafts, jnp.int32), jax.random.PRNGKey(0),
        [1, 2], [0, 0], [0, 0])
    out, n_emit = np.asarray(out), np.asarray(n_emit)
    for b in range(2):
        matches = int(np.cumprod(
            drafts[b] == targets[b, :k]).sum())
        assert n_emit[b] == matches + 1
        assert (out[b, :n_emit[b]] == targets[b, :n_emit[b]]).all()


# ---------------------------------------------------------------------
# speculative decoding (draft/verify over the paged arena)
# ---------------------------------------------------------------------
def test_speculative_greedy_bit_identical_and_one_trace(toy):
    """Draft = target (100%% greedy agreement): tokens match the plain
    engine bit-for-bit, every round commits k+1 tokens per verify launch,
    and the compile budget holds — one verify trace, at most one decode
    trace, per service."""
    cfg, params = toy
    prompt = np.arange(1, 8, dtype=np.int32)
    want = _tokens_of(_runtime(toy, sampler=SamplerConfig()), [
        GenerationRequest(rid=0, tokens=prompt, max_new_tokens=9),
        GenerationRequest(rid=1, tokens=np.arange(2, 7, dtype=np.int32),
                          max_new_tokens=7)])

    rt = _runtime(toy, sampler=SamplerConfig(),
                  draft_params=params, draft_cfg=cfg, speculate=3)
    got = _tokens_of(rt, [
        GenerationRequest(rid=0, tokens=prompt.copy(), max_new_tokens=9),
        GenerationRequest(rid=1, tokens=np.arange(2, 7, dtype=np.int32),
                          max_new_tokens=7)])
    assert got == want
    assert rt.verify_launches > 0
    assert rt.verify_traces == 1
    assert rt.decode_traces <= 1
    assert rt.draft_decode_traces <= 1
    # self-draft accepts everything: k+1 per launch until max_new clips
    assert rt.accepted_tokens >= 2 * rt.verify_launches


def test_speculative_stochastic_is_deterministic(toy):
    """Stochastic speculation reproduces bit-identically run-to-run (all
    its randomness flows through the counter streams)."""
    cfg, params = toy
    prompt = np.arange(1, 8, dtype=np.int32)
    runs = []
    for _ in range(2):
        rt = _runtime(toy, draft_params=params, draft_cfg=cfg, speculate=2)
        runs.append(_tokens_of(rt, [
            GenerationRequest(rid=4, tokens=prompt.copy(),
                              max_new_tokens=8)]))
    assert runs[0] == runs[1]


def test_speculate_category_gating(toy):
    """The -1 knob resolves by category: latency speculates when a draft
    is present, frequency never does; an explicit ask without a draft is
    a loud error."""
    cfg, params = toy
    lat = ParallelPlan(service="s", category=LAT, bs=2)
    frq = ParallelPlan(service="s", category=FREQ, bs=2)
    assert lat.resolved_speculate(True) > 0
    assert lat.resolved_speculate(False) == 0
    assert frq.resolved_speculate(True) == 0
    assert frq.resolved_n_samples() == 2         # fan to the batch size
    assert lat.resolved_n_samples() == 1
    with pytest.raises(ValueError, match="draft"):
        _runtime(toy, speculate=3)
    rt = _runtime(toy, category=LAT, draft_params=params, draft_cfg=cfg)
    assert rt.speculate_k > 0                    # category default armed


def test_speculative_park_degrades_not_corrupts(toy):
    """Parking a speculating slot drops its draft (resume is plain
    decode) and greedy tokens stay bit-identical."""
    cfg, params = toy
    prompt = np.arange(1, 8, dtype=np.int32)
    want = _tokens_of(_runtime(toy, sampler=SamplerConfig()), [
        GenerationRequest(rid=6, tokens=prompt, max_new_tokens=8)])
    rt = _runtime(toy, sampler=SamplerConfig(),
                  draft_params=params, draft_cfg=cfg, speculate=3)
    rt.submit(GenerationRequest(rid=6, tokens=prompt.copy(),
                                max_new_tokens=8))
    for _ in range(16):
        rt.step()
        state = rt.groups[0]
        if state.slots and state.slots[0].spec \
                and 2 <= len(state.slots[0].emitted) < 8:
            break
    state = rt.groups[0]
    assert state.slots and state.slots[0].spec
    rt._park_slot(0, state, state.slots[0], now=0.0)
    assert rt.spec_degraded == 1
    got = {(r.rid, r.sample): list(map(int, r.tokens)) for r in rt.drain()}
    assert got == want


# ---------------------------------------------------------------------
# n>1 parallel sampling (refcounted prompt-block forks)
# ---------------------------------------------------------------------
def test_parallel_samples_fork_and_diverge(toy):
    """n_samples=3 returns three results for one rid: distinct sample
    indices, distinct stochastic streams, shared-prompt blocks paid once,
    and clean teardown (no leaked slots, blocks, or sibling refs)."""
    rt = _runtime(toy, category=FREQ)
    rt.submit(GenerationRequest(rid=7, tokens=np.arange(1, 8, dtype=np.int32),
                                max_new_tokens=6, n_samples=3))
    res = rt.drain()
    assert sorted(r.sample for r in res) == [0, 1, 2]
    assert all(r.rid == 7 for r in res)
    streams = {tuple(map(int, r.tokens)) for r in res}
    assert len(streams) == 3                     # stochastic divergence
    assert rt.forks_spawned == 2
    # forks paid zero prefill compute: only the primary's prompt ran
    assert rt.prefill_tokens_computed == 7
    assert not rt._sibling_refs
    arena = rt.groups[0].arena
    assert len(arena._free_slots) == arena.capacity
    assert len(res) == 3


def test_parallel_samples_deterministic_and_batch_independent(toy):
    """Each sample's stream keys on (seed, sample_idx): the full fan
    reproduces exactly, alone or alongside other traffic."""
    def fan(extra):
        rt = _runtime(toy, category=FREQ)
        reqs = [GenerationRequest(rid=7, tokens=np.arange(1, 8, dtype=np.int32),
                                  max_new_tokens=5, n_samples=3)]
        if extra:
            reqs.append(GenerationRequest(
                rid=50, tokens=np.arange(4, 10, dtype=np.int32),
                max_new_tokens=7))
        out = _tokens_of(rt, reqs)
        return {k: v for k, v in out.items() if k[0] == 7}
    assert fan(False) == fan(True)


def test_fork_shortfall_under_slot_pressure(toy):
    """Asking for more samples than the group has slots spawns what fits
    and counts the rest — the primary always runs."""
    rt = _runtime(toy, category=FREQ, bs=2)
    rt.submit(GenerationRequest(rid=9, tokens=np.arange(1, 6, dtype=np.int32),
                                max_new_tokens=4, n_samples=4))
    res = rt.drain()
    assert len(res) == 2                          # primary + one fork
    assert rt.forks_spawned == 1
    assert rt.fork_shortfall >= 1


# ---------------------------------------------------------------------
# arena parking gate (satellite audit: ring layouts must not park)
# ---------------------------------------------------------------------
def test_ring_arena_is_not_parkable():
    """Sliding-window layouts store their window as per-slot state the
    next tenant overwrites, so ``parkable`` must gate them out — parking
    one and resuming would resurrect the wrong window."""
    dense = toy_config()
    a = KVArena(dense, model_api(dense).init_cache, capacity=2,
                max_seq_len=64, block_size=16)
    assert a.parkable

    ring = toy_config(sliding_window=16)
    r = KVArena(ring, model_api(ring).init_cache, capacity=2,
                max_seq_len=64, block_size=16)
    assert r._state_shapes                        # window rows are state
    assert not r.parkable
    s0 = r.alloc(32)
    with pytest.raises(ValueError, match="per-slot state"):
        r.park(s0)
