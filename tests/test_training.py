"""Training substrate: optimizers learn, microbatch equivalence, chunked
loss equivalence, checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import RequestStream, TokenPipeline
from repro.models import transformer as T
from repro.training import checkpoint
from repro.training.optimizer import Adafactor, AdamW, get_optimizer
from repro.training.train_step import (chunked_cross_entropy, make_loss_fn,
                                       make_train_step)


def _setup(dense_cfg, opt_name="adamw", lr=1e-2, **step_kw):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    opt = get_optimizer(opt_name, lr)
    state = opt.init(params)
    step = jax.jit(make_train_step(dense_cfg, opt, **step_kw))
    return params, opt, state, step


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_decreases(dense_cfg, opt_name):
    params, opt, state, step = _setup(dense_cfg, opt_name)
    pipe = TokenPipeline(vocab_size=dense_cfg.vocab_size, seq_len=32,
                         batch_size=8, seed=0)
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in pipe.batch(i % 3).items()}
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


class _GradSpy:
    """Identity 'optimizer' that records the accumulated gradient — lets the
    test compare grads directly (AdamW's sign-normalized update would
    amplify ~1e-8 grad noise into ±2*lr param flips)."""

    def init(self, params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(self, grads, state, params):
        return params, jax.tree.map(
            lambda g: g.astype(jnp.float32), grads)


def test_microbatch_equivalence(dense_cfg):
    """k=1 and k=4 microbatches accumulate (nearly) the same gradient."""
    pipe = TokenPipeline(vocab_size=dense_cfg.vocab_size, seq_len=16,
                         batch_size=8, seed=0)
    b = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    outs = []
    for k in (1, 4):
        spy = _GradSpy()
        step = jax.jit(make_train_step(dense_cfg, spy,
                                       num_microbatches=k))
        _, grads, m = step(params, spy.init(params), b)
        outs.append((grads, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-4)
    for a, b_ in zip(jax.tree.leaves(outs[0][0]),
                     jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(a, b_, rtol=5e-3, atol=1e-6)


def test_chunked_xent_matches_full(dense_cfg):
    B, L, V = 2, 24, dense_cfg.vocab_size
    h = jax.random.normal(jax.random.PRNGKey(0), (B, L, dense_cfg.d_model))
    y = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, V)
    params = T.init(jax.random.PRNGKey(2), dense_cfg)
    lf = lambda hh: T.logits_fn(params, dense_cfg, hh)
    full = chunked_cross_entropy(h, y, lf, chunk=L)
    chunked = chunked_cross_entropy(h, y, lf, chunk=7)  # ragged chunks
    assert float(full) == pytest.approx(float(chunked), rel=1e-5)


def test_chunked_xent_ignore_mask(dense_cfg):
    B, L = 2, 10
    h = jax.random.normal(jax.random.PRNGKey(0), (B, L, dense_cfg.d_model))
    y = jnp.full((B, L), -1)
    y = y.at[:, :3].set(5)
    params = T.init(jax.random.PRNGKey(2), dense_cfg)
    lf = lambda hh: T.logits_fn(params, dense_cfg, hh)
    loss_masked = chunked_cross_entropy(h, y, lf, chunk=4)
    loss_first3 = chunked_cross_entropy(h[:, :3], y[:, :3], lf, chunk=4)
    assert float(loss_masked) == pytest.approx(float(loss_first3), rel=1e-5)


def test_adafactor_state_is_factored(dense_cfg):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    opt = Adafactor()
    state = opt.init(params)
    p_bytes = sum(p.size * p.dtype.itemsize for p in jax.tree.leaves(params))
    s_bytes = sum(s.size * s.dtype.itemsize
                  for s in jax.tree.leaves((state.vr, state.vc)))
    adamw_bytes = 2 * 4 * sum(p.size for p in jax.tree.leaves(params))
    assert s_bytes < 0.2 * adamw_bytes


def test_checkpoint_roundtrip(tmp_path, dense_cfg):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    path = checkpoint.save(str(tmp_path / "ckpt.npz"), params, step=7)
    like = T.init(jax.random.PRNGKey(1), dense_cfg)   # different values
    restored = checkpoint.restore(str(tmp_path / "ckpt.npz"), like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.restored_step(str(tmp_path / "ckpt.npz")) == 7


def test_request_stream_rates():
    s = RequestStream(rate=50.0, horizon_s=20.0, seed=0)
    times = s.arrival_times()
    assert 600 < len(times) < 1400       # ~1000 expected
    bursty = RequestStream(rate=50.0, horizon_s=20.0, seed=0,
                           burstiness=8.0)
    tb = bursty.arrival_times()
    import numpy as np_
    cv2 = lambda a: float(np_.var(np_.diff(a)) / np_.mean(np_.diff(a))**2)
    assert cv2(tb) > cv2(times)          # burstier inter-arrivals
