"""SSSP placement: submodularity/monotonicity properties (hypothesis),
the 1/(1+P) approximation bound vs brute force, matroid feasibility, and
the cache-policy baselines."""
import itertools
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allocator import ParallelPlan, allocate
from repro.core.categories import (GPUSpec, Sensitivity, ServerSpec,
                                   ServiceSpec)
from repro.core.placement import (EPSILON_SERVER, PlacementProblem,
                                  approximation_bound, evaluate, feasible,
                                  matroid_count, place_lfu, place_lru,
                                  place_mfu, spf, sssp)

GPU = GPUSpec()


def _mk_problem(n_services=3, n_servers=3, demand_scale=50.0, seed=0,
                num_gpus=2):
    import numpy as np
    rng = np.random.default_rng(seed)
    services, plans = {}, {}
    for i in range(n_services):
        name = f"svc{i}"
        svc = ServiceSpec(
            name=name,
            flops_per_request=float(rng.uniform(1e9, 5e12)),
            weights_bytes=float(rng.uniform(1e8, 2e10)),
            vram_bytes=float(rng.uniform(5e8, 2.5e10)),
            slo_latency_s=1.0)
        services[name] = svc
        plans[name] = allocate(svc, GPU)
    servers = [ServerSpec(sid=i, num_gpus=num_gpus)
               for i in range(n_servers)]
    demand = {(l, s.sid): float(rng.uniform(0, demand_scale))
              for l in services for s in servers}
    return PlacementProblem(services=services, plans=plans, servers=servers,
                            demand=demand, period_s=10.0)


def _all_candidates(problem):
    return [(l, s.sid) for l in problem.services for s in problem.servers]


# ---------------------------------------------------------------------------
# properties of φ
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(0, 5))
def test_phi_monotone(seed, k):
    problem = _mk_problem(seed=seed)
    cands = _all_candidates(problem)
    import random
    r = random.Random(seed)
    theta = r.sample(cands, min(k, len(cands)))
    extra = r.choice([c for c in cands if c not in theta])
    assert evaluate(problem, theta + [extra]) >= \
        evaluate(problem, theta) - 1e-9


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_phi_submodular(seed):
    """Diminishing returns: for A ⊆ B and ξ ∉ B,
    φ(A+ξ) − φ(A) ≥ φ(B+ξ) − φ(B)  (Appendix A, Theorem A.1)."""
    problem = _mk_problem(seed=seed)
    cands = _all_candidates(problem)
    import random
    r = random.Random(seed ^ 0xABCDEF)
    b_size = r.randint(1, len(cands) - 1)
    B = r.sample(cands, b_size)
    A = B[: r.randint(0, b_size - 1)]
    xi = r.choice([c for c in cands if c not in B])
    gain_a = evaluate(problem, A + [xi]) - evaluate(problem, A)
    gain_b = evaluate(problem, B + [xi]) - evaluate(problem, B)
    assert gain_a >= gain_b - 1e-6


# ---------------------------------------------------------------------------
# approximation bound vs brute force
# ---------------------------------------------------------------------------

def _brute_force_opt(problem, candidates, max_size=4):
    best = 0.0
    for r in range(1, max_size + 1):
        for combo in itertools.combinations(candidates, r):
            ok = True
            chosen = []
            for c in combo:
                if not feasible(problem, chosen, c):
                    ok = False
                    break
                chosen.append(c)
            if ok:
                best = max(best, evaluate(problem, list(combo)))
    return best


@pytest.mark.parametrize("seed", range(6))
def test_greedy_beats_approximation_bound(seed):
    problem = _mk_problem(n_services=2, n_servers=2, seed=seed,
                          num_gpus=1, demand_scale=30.0)
    cands = _all_candidates(problem)
    theta = spf(problem, cands, [], lazy=False)
    phi_greedy = evaluate(problem, theta)
    phi_opt = _brute_force_opt(problem, cands)
    bound = approximation_bound(problem)
    assert phi_greedy >= bound * phi_opt - 1e-6, \
        f"greedy {phi_greedy} < {bound} * opt {phi_opt}"
    # empirically the paper observes far better than the bound; sanity:
    if phi_opt > 0:
        assert phi_greedy / phi_opt >= 0.5


def test_lazy_greedy_matches_eager():
    for seed in range(5):
        problem = _mk_problem(seed=seed)
        cands = _all_candidates(problem)
        eager = evaluate(problem, spf(problem, cands, [], lazy=False))
        lazy = evaluate(problem, spf(problem, cands, [], lazy=True))
        assert abs(eager - lazy) <= 1e-6 * max(1.0, eager)


# ---------------------------------------------------------------------------
# matroid feasibility / SSSP stages
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_sssp_never_overcommits(seed):
    problem = _mk_problem(seed=seed, n_services=4, n_servers=3)
    theta = sssp(problem)
    for server in problem.servers:
        used_c = sum(problem.compute_units(l) for l, n in theta
                     if n == server.sid)
        used_v = sum(problem.vram_units(l) for l, n in theta
                     if n == server.sid)
        assert used_c <= server.num_gpus + 1e-9
        assert used_v <= server.num_gpus + 1e-9


def test_sssp_priority_stage_first():
    problem = _mk_problem(seed=3)
    prio = [("svc0", 0)]
    problem = PlacementProblem(
        services=problem.services, plans=problem.plans,
        servers=problem.servers, demand=problem.demand,
        period_s=problem.period_s, priority_list=prio)
    theta = sssp(problem)
    assert theta[0] == ("svc0", 0)  # S1 placements precede S2


def test_epsilon_server_for_multi_gpu_services():
    """A service too large for any single server must land on ε (S3)."""
    big = ServiceSpec(name="big", flops_per_request=1e12,
                      weights_bytes=2e11, vram_bytes=10 * 16e9,
                      slo_latency_s=5.0)
    plan = allocate(big, GPU)
    assert plan.mp > 4
    servers = [ServerSpec(sid=i, num_gpus=4) for i in range(4)]
    problem = PlacementProblem(
        services={"big": big}, plans={"big": plan}, servers=servers,
        demand={("big", i): 10.0 for i in range(4)}, period_s=10.0)
    theta = sssp(problem)
    assert ("big", EPSILON_SERVER) in theta


def test_matroid_count_formula():
    problem = _mk_problem(seed=0)
    P = matroid_count(problem)
    a = [problem.compute_units(s) for s in problem.services]
    b = [problem.vram_units(s) for s in problem.services]
    assert P == math.ceil(max(a) / min(x for x in a if x > 0)) + \
        math.ceil(max(b) / min(x for x in b if x > 0))
    assert 0 < approximation_bound(problem) <= 0.5


# ---------------------------------------------------------------------------
# cache-policy baselines (Fig. 17b)
# ---------------------------------------------------------------------------

def test_cache_baselines_feasible_and_weaker():
    problem = _mk_problem(seed=7, n_services=4, n_servers=3,
                          demand_scale=200.0)
    hist = {s: float(i) for i, s in enumerate(problem.services)}
    for placer in (place_lru, place_lfu, place_mfu):
        theta = placer(problem, hist)
        for server in problem.servers:
            used = sum(problem.compute_units(l) for l, n in theta
                       if n == server.sid)
            assert used <= server.num_gpus + 1e-9
    phi_sssp = evaluate(problem, sssp(problem))
    phi_lru = evaluate(problem, place_lru(problem, hist))
    assert phi_sssp >= phi_lru - 1e-6  # state-aware >= recency heuristic


# ---------------------------------------------------------------------------
# incremental φ (PhiState) — must equal the reference evaluator exactly
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(0, 6))
def test_phistate_matches_evaluate(seed, k):
    from repro.core.placement import PhiState
    import random
    problem = _mk_problem(seed=seed, n_services=3, n_servers=3)
    cands = _all_candidates(problem) + \
        [(l, EPSILON_SERVER) for l in problem.services]
    r = random.Random(seed)
    theta = []
    state = PhiState(problem)
    for _ in range(k):
        cand = r.choice([c for c in cands if c not in theta])
        want_gain = evaluate(problem, theta + [cand]) \
            - evaluate(problem, theta)
        got_gain = state.gain(cand)
        assert abs(want_gain - got_gain) < 1e-6 * max(1.0, abs(want_gain))
        theta.append(cand)
        state.add(cand)
        assert abs(state.total() - evaluate(problem, theta)) < 1e-6


# ---------------------------------------------------------------------------
# online placement (§3.3)
# ---------------------------------------------------------------------------

def test_online_placement_feasible_and_reasonable():
    from repro.core.placement import OnlinePlacer, online_placement
    problem = _mk_problem(seed=11, n_services=4, n_servers=3,
                          demand_scale=100.0)
    order = list(problem.services) * 3
    theta = online_placement(problem, order)
    for server in problem.servers:
        used = sum(problem.compute_units(l) for l, n in theta
                   if n == server.sid)
        assert used <= server.num_gpus + 1e-9
    phi_online = evaluate(problem, theta)
    phi_offline = evaluate(problem, sssp(problem, include_epsilon=False))
    # online greedy should reach a sizable fraction of the offline solve
    assert phi_online >= 0.5 * phi_offline


def test_online_placer_rejects_when_full():
    from repro.core.placement import OnlinePlacer
    problem = _mk_problem(seed=4, n_services=2, n_servers=1, num_gpus=1)
    placer = OnlinePlacer(problem)
    placed = 0
    for _ in range(20):
        if placer.offer(list(problem.services)[0]):
            placed += 1
    assert placed < 20  # capacity eventually refuses
