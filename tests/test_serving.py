"""Serving substrate: BS/MF batch composition (Eq. 5 semantics), the
generation engine vs direct model decode, cache utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocator import ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models import transformer as T
from repro.serving import kvcache
from repro.serving.batching import (BSComposer, MFComposer, QueuedItem,
                                    make_composer)
from repro.serving.engine import GenerationRequest, ServiceRuntime
from repro.serving.sampler import SamplerConfig, sample

LAT = TaskCategory(Sensitivity.LATENCY, False)
FREQ = TaskCategory(Sensitivity.FREQUENCY, False)


def test_bs_composer_fifo_and_cap():
    plan = ParallelPlan(service="s", category=LAT, bs=3)
    c = BSComposer(plan)
    for i in range(5):
        c.add(QueuedItem(payload=i, rid=i))
    b = c.compose()
    assert [i.payload for i in b.items] == [0, 1, 2]
    assert len(c) == 2


def test_mf_composer_takes_identical_frames_per_stream():
    # bs=8, mf=2 -> inter_request_count = 4 streams x 2 frames
    plan = ParallelPlan(service="s", category=FREQ, bs=8, mf=2)
    c = MFComposer(plan)
    for stream in range(5):
        for f in range(3):
            c.add(QueuedItem(payload=(stream, f), stream=stream))
    b = c.compose(now=0.0)
    assert b.mf == 2 and len(b.streams) == 4
    per_stream = {}
    for item in b.items:
        per_stream.setdefault(item.stream, 0)
        per_stream[item.stream] += 1
    assert all(v == 2 for v in per_stream.values())  # identical counts


def test_mf_composer_waits_until_mf_frames_then_flushes_overdue():
    plan = ParallelPlan(service="s", category=FREQ, bs=8, mf=4)
    c = MFComposer(plan)
    c.add(QueuedItem(payload=0, stream=0, enqueued_s=0.0))
    assert c.compose(now=0.1, max_wait_s=1.0) is None   # not enough frames
    b = c.compose(now=2.0, max_wait_s=1.0)               # overdue flush
    assert b is not None and len(b.items) == 1


def test_make_composer_selects_by_category():
    freq_plan = ParallelPlan(service="s", category=FREQ, bs=8, mf=2)
    lat_plan = ParallelPlan(service="s", category=LAT, bs=8)
    assert isinstance(make_composer(freq_plan), MFComposer)
    assert isinstance(make_composer(lat_plan), BSComposer)


def test_sampler_greedy_and_topk():
    logits = jnp.array([[0.0, 5.0, 1.0], [3.0, 0.0, 0.1]])
    out = sample(logits, jax.random.PRNGKey(0))
    assert list(np.asarray(out)) == [1, 0]
    cfg = SamplerConfig(temperature=1.0, top_k=1)
    out = sample(logits, jax.random.PRNGKey(0), cfg)
    assert list(np.asarray(out)) == [1, 0]   # top-1 == greedy


def _toy_runtime(dense_cfg):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    plan = ParallelPlan(service="toy", category=LAT, bs=4)
    return params, ServiceRuntime(dense_cfg, params, plan)


def test_engine_matches_direct_greedy_decode(dense_cfg):
    """The slot engine must emit exactly the greedy continuation the raw
    model produces for a single request — in both serving modes."""
    params, rt = _toy_runtime(dense_cfg)
    prompt = np.arange(1, 8, dtype=np.int32)
    rt.submit(GenerationRequest(rid=0, tokens=prompt, max_new_tokens=5))
    res = rt.drain()[0]

    logits, cache = T.prefill(params, dense_cfg,
                              {"tokens": jnp.asarray(prompt[None])},
                              cache_size=len(prompt) + 5)
    want = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    want.append(int(tok[0]))
    for _ in range(4):
        logits, cache = T.decode_step(params, dense_cfg, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        want.append(int(tok[0]))
    assert list(res.tokens) == want

    plan = ParallelPlan(service="toy", category=LAT, bs=4)
    rt_sync = ServiceRuntime(dense_cfg, params, plan, mode="sync")
    rt_sync.submit(GenerationRequest(rid=0, tokens=prompt, max_new_tokens=5))
    assert list(rt_sync.drain()[0].tokens) == want


def test_engine_batches_multiple_requests(dense_cfg):
    _, rt = _toy_runtime(dense_cfg)
    for i in range(3):
        rt.submit(GenerationRequest(rid=i, tokens=np.arange(2 + i,
                                                            dtype=np.int32),
                                    max_new_tokens=3))
    res = rt.drain()
    assert sorted(r.rid for r in res) == [0, 1, 2]
    assert all(r.tokens.shape == (3,) for r in res)


def test_kvcache_utilities(dense_cfg):
    cache = T.init_cache(dense_cfg, batch_size=4, max_len=8)
    assert kvcache.batch_size(cache) == 4
    sel = kvcache.select_slots(cache, [0, 2])
    assert kvcache.batch_size(sel) == 2
    merged = kvcache.concat([sel, sel])
    assert kvcache.batch_size(merged) == 4
    assert kvcache.cache_bytes(cache) > 0
