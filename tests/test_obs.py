"""Observability layer (``repro/obs``): byte-inertness of the disabled
path, span-tree invariants of the request-lifecycle tracer (including
park->resume and speculative rounds), metrics exposition round-trips,
and the telemetry -> ``SimConfig`` calibration loop.

The headline acceptance gate: obs OFF (the default) must leave emitted
greedy tokens bit-identical and compile counts unchanged versus obs ON —
the tracer and registry are host-side annotators, never participants.
"""
import dataclasses
import json
import math
import os

import jax
import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import toy_config
from repro.core.allocator import ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models import transformer as T
from repro.obs import (Histogram, MetricsRegistry, ServiceTelemetry,
                       Tracer, calibrate, merge_telemetry,
                       parse_prometheus_text, telemetry_from_runtime,
                       telemetry_from_snapshot, telemetry_from_steps,
                       validate_chrome_trace)
from repro.serving.engine import GenerationRequest, ServiceRuntime
from repro.simulator.engine import SimConfig

LAT = TaskCategory(Sensitivity.LATENCY, False)
FREQ = TaskCategory(Sensitivity.FREQUENCY, False)

# the hypothesis interleaving test drives a real engine per example, so
# its budget is its own knob (the CI hypothesis job raises it)
OBS_EXAMPLES = int(os.environ.get("OBS_EXAMPLES", "5"))


_TOY = None


def _toy_params():
    """Module-level memo (not a fixture): the hypothesis fallback shim
    cannot inject pytest fixtures into ``@given`` tests."""
    global _TOY
    if _TOY is None:
        cfg = toy_config()
        _TOY = (cfg, T.init(jax.random.PRNGKey(0), cfg))
    return _TOY


@pytest.fixture(scope="module")
def toy():
    return _toy_params()


def _runtime(toy, *, bs=4, category=LAT, admission=None, **kw):
    cfg, params = toy
    plan = ParallelPlan(service="toy", category=category, bs=bs)
    if admission is not None:
        plan = dataclasses.replace(plan, admission=admission)
    return ServiceRuntime(cfg, params, plan, **kw)


def _reqs(n, *, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    return [GenerationRequest(
        rid=i, tokens=rng.integers(1, 257, 5 + i % 3).astype(np.int32),
        max_new_tokens=max_new) for i in range(n)]


def _serve(rt, reqs):
    for r in reqs:
        rt.submit(r)
    return {r.rid: tuple(int(x) for x in r.tokens) for r in rt.drain()}


def _flatten(spans):
    out = []
    for s in spans:
        out.append(s)
        out.extend(_flatten(s.children))
    return out


def _check_tree(s, lo=-math.inf, hi=math.inf):
    """Balanced-tree invariants: every span's interval is well-formed,
    inside its parent, and siblings start in monotonic order."""
    assert lo <= s.start <= s.end <= hi, (s.name, s.start, s.end, lo, hi)
    t = s.start
    for c in s.children:
        assert c.start >= t, (s.name, c.name, c.start, t)
        _check_tree(c, s.start, s.end)
        t = c.start


@pytest.fixture(scope="module")
def basic_run(toy):
    """One traced + metered serve shared by the lifecycle tests."""
    tracer, metrics = Tracer(), MetricsRegistry()
    rt = _runtime(toy, tracer=tracer, metrics=metrics)
    toks = _serve(rt, _reqs(4, seed=1))
    return rt, tracer, metrics, toks


@pytest.fixture(scope="module")
def spec_run(toy):
    """A speculative (self-draft) serve with obs on, plus the recorded
    per-step ``StepStats`` — feeds the span and calibration tests."""
    cfg, params = toy
    tracer, metrics = Tracer(), MetricsRegistry()
    rt = ServiceRuntime(cfg, params,
                        ParallelPlan(service="toy", category=LAT, bs=4),
                        kvcache_impl="paged", draft_params=params,
                        draft_cfg=cfg, speculate=3,
                        tracer=tracer, metrics=metrics)
    rng = np.random.default_rng(3)
    for i in range(3):
        rt.submit(GenerationRequest(
            rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                       6 + 2 * i).astype(np.int32),
            max_new_tokens=8))
    steps = []
    while rt.pending() or rt.in_flight():
        steps.append(rt.step())
    return rt, tracer, metrics, steps


# ---------------------------------------------------------------------
# byte-inertness: obs off == obs on, to the bit and to the compile
# ---------------------------------------------------------------------
def test_obs_disabled_is_byte_inert(toy):
    def run(**obs_kw):
        rt = _runtime(toy, **obs_kw)
        return (_serve(rt, _reqs(6, seed=2)), rt.decode_traces,
                rt.prefill_traces)

    plain = run()
    traced = run(tracer=Tracer(), metrics=MetricsRegistry())
    assert plain[0] == traced[0]        # bit-identical greedy tokens
    assert plain[1:] == traced[1:]      # identical compile counts
    assert plain[1] == 1                # and still exactly one decode trace


# ---------------------------------------------------------------------
# lifecycle span trees
# ---------------------------------------------------------------------
def test_request_lifecycle_span_tree(basic_run):
    rt, tracer, _, toks = basic_run
    for rid, tokens in toks.items():
        tid = str(rid)
        assert tracer.open_spans("toy", tid) == []   # balanced
        roots, instants = tracer.span_tree("toy", tid)
        assert len(roots) == 1 and roots[0].name == "request"
        names = [c.name for c in roots[0].children]
        assert names == ["queued", "prefill", "decode"]
        assert roots[0].args.get("outcome") == "served"
        decode = roots[0].children[-1]
        assert decode.args.get("tokens") == len(tokens)
        assert [i.name for i in instants] == ["first_token"]
        assert roots[0].children[1].end <= instants[0].start + 1e-9
        _check_tree(roots[0])


def test_engine_phase_timeline(basic_run):
    _, tracer, _, _ = basic_run
    assert ("toy", "engine") in tracer.timelines()
    phases = [e for e in tracer.events() if e[2] == "engine"]
    names = {e[3] for e in phases}
    assert {"step", "evict", "admit", "fused_decode"} <= names
    # every phase is a finished complete event with non-negative duration
    assert all(e[0] == "X" and e[5] >= e[4] for e in phases)
    # one "step" span per scheduling round, covering its sub-phases
    steps = [e for e in phases if e[3] == "step"]
    assert len(steps) >= 4


def test_park_resume_span_sequence(toy):
    """SDF preemption parks a straggler mid-decode; its timeline must
    read decode -> parked -> decode with the resume annotated."""
    cfg, params = toy
    tracer = Tracer()
    rt = _runtime(toy, bs=2, admission="sdf", tracer=tracer)
    rng = np.random.default_rng(7)
    t = 0.0

    def drain():
        nonlocal t
        while rt.pending() or rt.in_flight():
            rt.step(now=t)
            t += 1.0
            assert t < 5000.0, "engine failed to drain"

    # warmup teaches the controller the round clock (cold SDF is FIFO)
    for i in range(2):
        rt.submit(GenerationRequest(
            rid=1000 + i,
            tokens=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=4), now=t)
    drain()
    # two deadline-less stragglers fill both slots...
    for i in range(2):
        rt.submit(GenerationRequest(
            rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                       6).astype(np.int32),
            max_new_tokens=24), now=t)
    for _ in range(2):
        rt.step(now=t)
        t += 1.0
    # ...then urgent deadlined shorts force a park
    for i in range(4):
        rt.submit(GenerationRequest(
            rid=100 + i,
            tokens=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=4, deadline_s=t + 14.0), now=t)
        for _ in range(3):
            rt.step(now=t)
            t += 1.0
    drain()
    assert rt.admission.preemptions >= 1
    parked_tids = [
        tid for pid, tid in tracer.timelines()
        if pid == "toy" and tid != "engine"
        and any(s.name == "parked"
                for s in _flatten(tracer.span_tree("toy", tid)[0]))]
    assert parked_tids
    for tid in parked_tids:
        roots, _ = tracer.span_tree("toy", tid)
        assert len(roots) == 1 and roots[0].name == "request"
        _check_tree(roots[0])
        seq = [c.name for c in roots[0].children]
        for j, name in enumerate(seq):
            if name == "parked":
                assert seq[j - 1] == "decode" and seq[j + 1] == "decode"
        assert any(c.name == "decode" and c.args.get("resumed")
                   for c in roots[0].children)


def test_speculative_round_spans(spec_run):
    rt, tracer, _, _ = spec_run
    assert rt.verify_launches > 0
    rounds = []
    for i in range(3):
        roots, _ = tracer.span_tree("toy", str(i))
        assert len(roots) == 1 and roots[0].name == "request"
        _check_tree(roots[0])
        decodes = [c for c in roots[0].children if c.name == "decode"]
        assert decodes
        rounds += [g for d in decodes for g in d.children
                   if g.name == "spec_round"]
    assert rounds
    assert all("accepted" in g.args and g.args["k"] == 3 for g in rounds)
    engine = {s.name
              for s in _flatten(tracer.span_tree("toy", "engine")[0])}
    assert "verify" in engine and "step" in engine


@settings(max_examples=OBS_EXAMPLES, deadline=None)
@given(specs=st.lists(
    st.tuples(st.integers(3, 10),     # prompt length
              st.integers(1, 6),      # max_new_tokens
              st.integers(0, 3)),     # engine rounds before next submit
    min_size=1, max_size=6))
def test_random_interleavings_yield_wellformed_trees(specs):
    """Property: ANY interleaving of submissions and scheduling rounds
    leaves every request timeline balanced (no open spans), rooted at a
    single ``request`` span, with monotonic properly-nested children."""
    tracer = Tracer()
    rt = _runtime(_toy_params(), bs=2, tracer=tracer)
    rng = np.random.default_rng(0)
    for rid, (plen, max_new, gap) in enumerate(specs):
        rt.submit(GenerationRequest(
            rid=rid, tokens=rng.integers(1, 257, plen).astype(np.int32),
            max_new_tokens=max_new))
        for _ in range(gap):
            rt.step()
    rt.drain()
    for rid in range(len(specs)):
        tid = str(rid)
        assert tracer.open_spans("toy", tid) == []
        roots, instants = tracer.span_tree("toy", tid)
        assert len(roots) == 1 and roots[0].name == "request"
        _check_tree(roots[0])
        assert all(roots[0].start <= i.start <= roots[0].end
                   for i in instants)


# ---------------------------------------------------------------------
# tracer primitives
# ---------------------------------------------------------------------
def test_tracer_ring_bound_and_close_semantics():
    ticks = iter(range(1000))
    tr = Tracer(capacity=4, clock=lambda: float(next(ticks)))
    # close() ends every open span innermost-first, args on the outermost
    tr.begin("p", "1", "request")
    tr.begin("p", "1", "queued")
    tr.close("p", "1", verdict="REJECT")
    roots, _ = tr.span_tree("p", "1")
    assert [s.name for s in roots] == ["request"]
    assert roots[0].args == {"verdict": "REJECT"}
    assert roots[0].children[0].name == "queued"
    assert tr.open_spans("p", "1") == []
    tr.end("p", "1")                    # end with nothing open: no-op
    # ring bound: oldest events drop, counters keep the truth
    for i in range(8):
        tr.instant("p", "1", f"i{i}")
    assert len(tr.events()) == 4
    assert tr.dropped == 6              # 2 spans + 8 instants, cap 4
    assert tr.emitted == 10


def test_chrome_trace_export_and_validation(basic_run, tmp_path):
    _, tracer, _, toks = basic_run
    path = tmp_path / "trace.json"
    tracer.export(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == len(doc["traceEvents"])
    thread_names = {ev["args"]["name"] for ev in doc["traceEvents"]
                    if ev.get("ph") == "M"
                    and ev["name"] == "thread_name"}
    assert {str(r) for r in toks} <= thread_names
    assert "engine" in thread_names
    with pytest.raises(ValueError):
        validate_chrome_trace({})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 1}]})


# ---------------------------------------------------------------------
# metrics: bucket math + exposition round-trips
# ---------------------------------------------------------------------
def test_histogram_bucket_math():
    h = Histogram("h", "t", buckets=(1, 2, 4))
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v, service="s")
    val = h.value(service="s")
    assert val["buckets"] == {"1": 2, "2": 2, "4": 3, "+Inf": 4}
    assert val["count"] == 4 and val["sum"] == pytest.approx(104.5)
    # cumulative counts are monotone by construction in the exposition
    lines = h.expose()
    bucket_counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines
                     if "_bucket" in ln]
    assert bucket_counts == sorted(bucket_counts)


def test_prometheus_roundtrip(basic_run):
    rt, _, metrics, toks = basic_run
    parsed = parse_prometheus_text(metrics.prometheus_text())
    assert parsed['epara_requests_finished_total{service="toy"}'] \
        == len(toks)
    assert parsed['epara_tokens_generated_total{service="toy"}'] \
        == sum(len(t) for t in toks.values())
    assert parsed['epara_ttft_seconds_count{service="toy"}'] == len(toks)
    assert parsed['epara_decode_compiles{service="toy"}'] \
        == rt.decode_traces == 1
    assert parsed['epara_ttft_seconds_bucket{service="toy",le="+Inf"}'] \
        == len(toks)
    with pytest.raises(ValueError):
        parse_prometheus_text("")
    with pytest.raises(ValueError):
        parse_prometheus_text('broken{label="x" 3')


# ---------------------------------------------------------------------
# calibration: telemetry -> SimConfig
# ---------------------------------------------------------------------
def test_calibration_steps_and_runtime_agree(spec_run):
    rt, _, _, steps = spec_run
    a = telemetry_from_steps("toy", steps, spec_k=3)
    b = telemetry_from_runtime("toy", rt)
    assert a.accepted_tokens == b.accepted_tokens == rt.accepted_tokens
    assert a.verify_launches == b.verify_launches == rt.verify_launches
    assert a.prefill_tokens_computed == b.prefill_tokens_computed
    assert a.prefill_seconds == pytest.approx(b.prefill_seconds)
    cal = calibrate({"toy": b})
    per_launch = rt.accepted_tokens / rt.verify_launches
    expected = min(1.0, max(0.0, (per_launch - 1.0) / 3))
    assert cal.spec_accept_rate == pytest.approx(expected)
    assert expected > 0.5       # a self-draft accepts nearly every token


def test_calibration_snapshot_roundtrip(spec_run):
    rt, _, metrics, _ = spec_run
    tel = telemetry_from_snapshot(metrics.snapshot())
    assert "toy" in tel
    s, d = tel["toy"], telemetry_from_runtime("toy", rt)
    assert (s.spec_k, s.accepted_tokens, s.verify_launches) \
        == (d.spec_k, d.accepted_tokens, d.verify_launches)
    assert s.prefill_tokens_computed == d.prefill_tokens_computed
    assert s.prefix_hit_tokens == d.prefix_hit_tokens
    assert s.prefill_seconds == pytest.approx(d.prefill_seconds)
    assert s.spec_accept_rate == pytest.approx(d.spec_accept_rate)


def test_calibration_prefix_hit_rate(toy):
    cfg, params = toy
    rt = _runtime(toy, category=FREQ, kvcache_impl="paged",
                  max_seq_len=160, block_size=16)
    rng = np.random.default_rng(11)
    prefix = rng.integers(1, cfg.vocab_size, 64).astype(np.int32)

    def wave(rids):
        for i in rids:
            rt.submit(GenerationRequest(
                rid=i, tokens=np.concatenate([
                    prefix, rng.integers(1, cfg.vocab_size,
                                         16).astype(np.int32)]),
                max_new_tokens=4))
        rt.drain()

    wave([0])                   # warm request populates the cache
    wave(range(1, 5))           # the repeated-prefix wave hits it
    assert rt.prefix_hit_tokens > 0
    tel = telemetry_from_runtime("toy", rt)
    expected = rt.prefix_hit_tokens / (rt.prefix_hit_tokens
                                       + rt.prefill_tokens_computed)
    assert tel.prefix_hit_rate == pytest.approx(expected)
    cal = calibrate({"toy": tel}, base=SimConfig(prefill_token_s=2e-4))
    assert cal.prefix_hit_rates["toy"] == pytest.approx(expected)
    assert 0.0 < cal.prefix_hit_rates["toy"] < 1.0
    assert cal.prefill_token_s > 0.0    # measured, replacing the base


def test_calibration_cold_run_keeps_base():
    """A run that measured nothing calibrates to exactly the base
    config — the loop is safe to run unconditionally."""
    base = SimConfig(spec_accept_rate=0.7, prefill_token_s=2e-4,
                     prefix_hit_rates={"svc": 0.5})
    cal = calibrate({"svc": ServiceTelemetry("svc")}, base=base)
    assert cal.spec_accept_rate == 0.7
    assert cal.prefill_token_s == 2e-4
    assert dict(cal.prefix_hit_rates) == {"svc": 0.5}


def test_merge_telemetry_sums_and_guards_spec_k():
    a = ServiceTelemetry("s", spec_k=3, accepted_tokens=8,
                         verify_launches=2, prefix_hit_tokens=10,
                         prefill_tokens_computed=30, prefill_seconds=0.3,
                         decode_steps=5)
    b = ServiceTelemetry("s", spec_k=3, accepted_tokens=4,
                         verify_launches=1, prefix_hit_tokens=2,
                         prefill_tokens_computed=10, prefill_seconds=0.1,
                         decode_steps=2)
    m = merge_telemetry([a, b])
    assert set(m) == {"s"}
    assert m["s"].accepted_tokens == 12
    assert m["s"].verify_launches == 3
    assert m["s"].prefix_hit_tokens == 12
    assert m["s"].prefill_tokens_computed == 40
    assert m["s"].prefill_seconds == pytest.approx(0.4)
    assert a.accepted_tokens == 8       # inputs are copied, not mutated
    with pytest.raises(ValueError):
        merge_telemetry([a, ServiceTelemetry("s", spec_k=2,
                                             verify_launches=1)])
