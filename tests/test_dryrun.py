"""Sharding rules + a subprocess mini dry-run (the real 512-device sweep is
launch/dryrun.py; here a reduced config lowers+compiles on 8 placeholder
devices so CI exercises the whole path without the big compile bill)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as meshlib


def test_pick_only_shards_divisible_dims():
    mesh = jax.make_mesh((1,), ("model",))   # single-device mesh: no-op
    spec = meshlib._pick(mesh, (8, 16), {"model": [1]})
    assert spec == P(None, None)


def test_param_rules_shape_awareness():
    import numpy as np

    class FakeMesh:
        shape = {"data": 4, "model": 8}

    leafs = {
        "embed": {"embedding": jax.ShapeDtypeStruct((32000, 512), "float32"),
                  "unembed": jax.ShapeDtypeStruct((512, 32000), "float32")},
        "blocks": {"attn": {"wq": jax.ShapeDtypeStruct((4, 512, 256),
                                                       "float32")},
                   "mlp": {"w_down": jax.ShapeDtypeStruct((4, 1024, 512),
                                                          "float32")},
                   "ln1": {"w": jax.ShapeDtypeStruct((4, 512), "float32")}},
    }
    specs = meshlib.param_specs(FakeMesh, leafs, fsdp=True)
    assert specs["embed"]["embedding"] == P("model", "data")
    assert specs["embed"]["unembed"] == P("data", "model")
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["blocks"]["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["blocks"]["ln1"]["w"] == P()   # norms replicate


def test_cache_specs_prefer_heads_then_headdim():
    class FakeMesh:
        shape = {"data": 4, "model": 8}

    cache = {"k": jax.ShapeDtypeStruct((2, 8, 64, 8, 128), "float32"),
             "len": jax.ShapeDtypeStruct((), "int32")}
    specs = meshlib.cache_specs(FakeMesh, cache)
    assert specs["k"] == P(None, "data", None, "model", None)
    # kv=3 heads not divisible by 8 -> head_dim picked instead
    cache2 = {"k": jax.ShapeDtypeStruct((2, 8, 64, 3, 128), "float32"),
              "len": jax.ShapeDtypeStruct((), "int32")}
    specs2 = meshlib.cache_specs(FakeMesh, cache2)
    assert specs2["k"] == P(None, "data", None, None, "model")


def test_batch_specs_replicate_batch_one():
    class FakeMesh:
        shape = {"data": 4, "model": 8}

    specs = meshlib.batch_specs(
        FakeMesh, {"token": jax.ShapeDtypeStruct((1,), "int32")})
    assert specs["token"] == P(None)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, sys
    import jax
    from repro.configs import get_config, reduced
    from repro.launch import mesh as meshlib
    from repro.launch import steps as steplib
    from repro.models.config import SHAPES_BY_NAME
    import repro.configs as C

    arch, shape_name = sys.argv[1], sys.argv[2]
    small = reduced(get_config(arch), d_model=128, num_heads=4,
                    num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512)
    # patch the registry so build_step sees the reduced config
    C.ARCHS[arch] = small
    shape = dataclasses.replace(SHAPES_BY_NAME[shape_name],
                                seq_len=64, global_batch=8)
    steplib.SHAPES_BY_NAME = dict(SHAPES_BY_NAME)
    steplib.SHAPES_BY_NAME[shape_name] = shape
    mesh = meshlib.make_mesh((2, 4), ("data", "model"))
    bundle = steplib.build_step(arch, shape_name, mesh, microbatches=2)
    lowered = steplib.lower_step(bundle)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older JAX wraps the dict in a list
        ca = ca[0]
    print(json.dumps({"ok": True, "flops": float(ca.get("flops", 0))}))
""")


@pytest.mark.parametrize("arch,shape", [
    ("codeqwen1.5-7b", "train_4k"),
    ("mixtral-8x7b", "prefill_32k"),
    ("mamba2-2.7b", "decode_32k"),
    ("zamba2-7b", "long_500k"),
])
def test_mini_dryrun_subprocess(arch, shape, tmp_path):
    prog = tmp_path / "prog.py"
    prog.write_text(_SUBPROCESS_PROG)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, str(prog), arch, shape],
                         capture_output=True, text=True, timeout=540,
                         env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
