"""Continuous-batching serving data plane: slot admit/evict loop, cache
pytree utilities on flat and nested layouts, sync-mode parity, and the
decode-step savings the slot loop exists for."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocator import ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models import ssm as S
from repro.models import transformer as T
from repro.serving import kvcache
from repro.serving.batching import BSComposer, MFComposer, QueuedItem
from repro.serving.engine import GenerationRequest, ServiceRuntime
from repro.serving.sampler import SamplerConfig, sample

from conftest import toy_config

LAT = TaskCategory(Sensitivity.LATENCY, False)
FREQ = TaskCategory(Sensitivity.FREQUENCY, False)


# ---------------------------------------------------------------------------
# kvcache utilities: flat, nested, and stateful (no-seq-axis) caches
# ---------------------------------------------------------------------------

def test_kvcache_flat_select_concat_bytes(dense_cfg):
    cache = T.init_cache(dense_cfg, batch_size=4, max_len=8)
    assert kvcache.batch_size(cache) == 4
    sel = kvcache.select_slots(cache, [0, 2])
    assert kvcache.batch_size(sel) == 2
    merged = kvcache.concat([sel, sel])
    assert kvcache.batch_size(merged) == 4
    assert kvcache.cache_bytes(cache) > 0
    assert kvcache.cache_bytes(sel) == kvcache.cache_bytes(cache) // 2


def test_kvcache_nested_pytree():
    nested = {"layers": {"k": jnp.arange(2 * 3 * 8 * 2 * 4, dtype=jnp.float32
                                         ).reshape(2, 3, 8, 2, 4),
                         "v": jnp.zeros((2, 3, 8, 2, 4))},
              "len": jnp.asarray(5, jnp.int32)}
    sel = kvcache.select_slots(nested, [2, 0])
    assert kvcache.batch_size(sel) == 2
    np.testing.assert_array_equal(np.asarray(sel["layers"]["k"][:, 0]),
                                  np.asarray(nested["layers"]["k"][:, 2]))
    merged = kvcache.merge([sel, nested])
    assert kvcache.batch_size(merged) == 5
    assert list(np.asarray(kvcache.lens(merged))) == [5] * 5


def test_kvcache_merge_ragged_capacity_and_lens(dense_cfg):
    """Admission merge: per-slot lens survive, shorter KV capacity is
    end-padded up to the longest member's."""
    a = T.init_cache(dense_cfg, batch_size=2, max_len=8)
    a = kvcache.with_lens(a, jnp.array([3, 5]))
    b = T.init_cache(dense_cfg, batch_size=1, max_len=12)
    merged = kvcache.merge([a, b])
    assert kvcache.batch_size(merged) == 3
    assert merged["k"].shape[2] == 12
    assert list(np.asarray(kvcache.lens(merged))) == [3, 5, 0]


def test_kvcache_pad_to_refuses_shrink(dense_cfg):
    big = T.init_cache(dense_cfg, batch_size=1, max_len=12)
    small = T.init_cache(dense_cfg, batch_size=1, max_len=8)
    with pytest.raises(ValueError):
        kvcache.pad_to(big, small)


def test_kvcache_ssm_state_cache():
    cfg = toy_config(family="ssm", ssm_state=4, ssm_headdim=16)
    cache = S.init_cache(cfg, batch_size=3, max_len=8)
    sel = kvcache.select_slots(cache, [1])
    merged = kvcache.merge([sel, cache])
    assert kvcache.batch_size(merged) == 4


# ---------------------------------------------------------------------------
# capacity-aware composition + partial-flush frame reporting
# ---------------------------------------------------------------------------

def test_bs_composer_limit_fills_only_free_slots():
    plan = ParallelPlan(service="s", category=LAT, bs=8)
    c = BSComposer(plan)
    for i in range(6):
        c.add(QueuedItem(payload=i, rid=i))
    b = c.compose(limit=2)
    assert [i.payload for i in b.items] == [0, 1]
    assert len(c) == 4
    assert c.compose(limit=0) is None
    c.push_front(b.items[0])
    assert c.compose(limit=1).items[0].payload == 0


def test_mf_composer_limit_and_partial_flush_reporting():
    plan = ParallelPlan(service="s", category=FREQ, bs=8, mf=4)
    c = MFComposer(plan)
    # starved stream: only 2 of the plan's 4 frames arrived
    for f in range(2):
        c.add(QueuedItem(payload=f, stream=7, enqueued_s=0.0))
    b = c.compose(now=5.0, max_wait_s=1.0)       # overdue partial flush
    assert b is not None and len(b.items) == 2
    assert b.mf == 2                             # ACTUAL frames, not plan mf
    assert b.frames_per_stream == {7: 2}

    # limit smaller than mf still admits (partial mf) instead of stalling
    for s in (0, 1):
        for f in range(4):
            c.add(QueuedItem(payload=(s, f), stream=s))
    b = c.compose(now=0.0, limit=2)
    assert b.size == 2 and b.mf == 2


def test_mf_composer_full_batch_reports_plan_mf():
    plan = ParallelPlan(service="s", category=FREQ, bs=8, mf=2)
    c = MFComposer(plan)
    for stream in range(4):
        for f in range(2):
            c.add(QueuedItem(payload=(stream, f), stream=stream))
    b = c.compose(now=0.0)
    assert b.mf == 2 and b.frames_per_stream == {s: 2 for s in range(4)}


# ---------------------------------------------------------------------------
# masked sampling
# ---------------------------------------------------------------------------

def test_sampler_masks_done_slots():
    logits = jnp.array([[0.0, 5.0, 1.0], [3.0, 0.0, 0.1]])
    out = sample(logits, jax.random.PRNGKey(0),
                 live=jnp.array([True, False]), fill_token=-7)
    assert list(np.asarray(out)) == [1, -7]
    out = sample(logits, jax.random.PRNGKey(0), SamplerConfig(temperature=1.0),
                 live=jnp.array([False, True]), fill_token=0)
    assert int(out[0]) == 0


# ---------------------------------------------------------------------------
# the admit/evict loop itself
# ---------------------------------------------------------------------------

def _direct_greedy(params, cfg, prompt, n):
    logits, cache = T.prefill(params, cfg,
                              {"tokens": jnp.asarray(prompt[None])},
                              cache_size=len(prompt) + n)
    toks = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(n - 1):
        logits, cache = T.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(tok[0]))
    return toks


@pytest.fixture
def toy_engine(dense_cfg):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)

    def make(mode="continuous", bs=4):
        plan = ParallelPlan(service="toy", category=LAT, bs=bs)
        return ServiceRuntime(dense_cfg, params, plan, mode=mode)
    return params, make


def test_continuous_matches_sync_token_for_token(dense_cfg, toy_engine):
    """Acceptance: identical greedy tokens in both modes on a fixed seed
    (equal-length prompts so sync-mode left-padding is identical too)."""
    params, make = toy_engine
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, dense_cfg.vocab_size, 6).astype(np.int32)
               for _ in range(6)]
    max_new = [5, 2, 7, 3, 1, 4]
    got = {}
    for mode in ("continuous", "sync"):
        rt = make(mode=mode, bs=3)
        for i, (p, n) in enumerate(zip(prompts, max_new)):
            rt.submit(GenerationRequest(rid=i, tokens=p, max_new_tokens=n))
        res = rt.drain()
        assert sorted(r.rid for r in res) == list(range(6))
        got[mode] = {r.rid: list(r.tokens) for r in res}
    assert got["continuous"] == got["sync"]


def test_continuous_matches_direct_decode_with_ragged_prompts(dense_cfg,
                                                              toy_engine):
    """Stronger than sync parity: per-request individual prefill + per-slot
    lens make every slot numerically independent of its batch peers, so
    each result equals the raw model's own greedy continuation even with
    mixed prompt lengths and mixed max_new_tokens."""
    params, make = toy_engine
    prompts = [np.arange(1, 5 + i, dtype=np.int32) for i in range(4)]
    max_new = [3, 6, 2, 5]
    rt = make(bs=4)
    for i, (p, n) in enumerate(zip(prompts, max_new)):
        rt.submit(GenerationRequest(rid=i, tokens=p, max_new_tokens=n))
    res = {r.rid: list(r.tokens) for r in rt.drain()}
    for i, (p, n) in enumerate(zip(prompts, max_new)):
        assert res[i] == _direct_greedy(params, dense_cfg, p, n)


def test_early_eos_frees_slot_for_queued_request(dense_cfg, toy_engine):
    """A request whose eos fires early is evicted and its slot reused."""
    params, make = toy_engine
    prompt = np.arange(1, 8, dtype=np.int32)
    want = _direct_greedy(params, dense_cfg, prompt, 8)
    eos = want[2]                # greedy path emits this at step 3
    rt = make(bs=1)              # single slot: reuse is observable
    rt.submit(GenerationRequest(rid=0, tokens=prompt, max_new_tokens=8,
                                eos_token=eos))
    rt.submit(GenerationRequest(rid=1, tokens=prompt, max_new_tokens=2))
    res = {r.rid: r for r in rt.drain()}
    assert list(res[0].tokens) == want[:3]       # stopped at eos, not 8
    assert res[0].decode_steps == 2
    assert list(res[1].tokens) == want[:2]       # admitted after eviction
    assert rt.in_flight() == 0 and rt.pending() == 0


def test_late_arrival_is_admitted_mid_decode(dense_cfg, toy_engine):
    params, make = toy_engine
    p0 = np.arange(1, 7, dtype=np.int32)
    p1 = np.arange(2, 9, dtype=np.int32)
    rt = make(bs=4)
    rt.submit(GenerationRequest(rid=0, tokens=p0, max_new_tokens=8))
    rt.step()
    rt.step()                     # rid 0 already two tokens deep
    assert rt.in_flight() == 1
    rt.submit(GenerationRequest(rid=1, tokens=p1, max_new_tokens=3))
    rt.step()
    assert rt.in_flight() == 2    # admitted mid-decode, no barrier
    res = {r.rid: list(r.tokens) for r in rt.drain()}
    assert res[0] == _direct_greedy(params, dense_cfg, p0, 8)
    assert res[1] == _direct_greedy(params, dense_cfg, p1, 3)


def test_continuous_uses_fewer_decode_steps_on_bursty_workload(dense_cfg,
                                                               toy_engine):
    """Acceptance: a bursty mixed-max_new workload completes in fewer fused
    decode steps than the batch-sync barrier path (asserted on step count,
    not wall clock)."""
    params, make = toy_engine
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, dense_cfg.vocab_size, 5).astype(np.int32)
               for _ in range(8)]
    max_new = [12, 2, 2, 2, 12, 2, 2, 2]     # two stragglers per wave
    steps = {}
    for mode in ("continuous", "sync"):
        rt = make(mode=mode, bs=4)
        for i, (p, n) in enumerate(zip(prompts, max_new)):
            rt.submit(GenerationRequest(rid=i, tokens=p, max_new_tokens=n))
        res = rt.drain()
        assert len(res) == 8
        steps[mode] = rt.decode_steps
    assert steps["continuous"] < steps["sync"], steps


def test_per_request_timing_is_per_slot(dense_cfg, toy_engine):
    """decode_steps (and so decode_s) reflect each request's own lifetime,
    not the batch-wide max."""
    params, make = toy_engine
    rt = make(bs=4)
    prompts = [np.arange(1, 6, dtype=np.int32)] * 2
    rt.submit(GenerationRequest(rid=0, tokens=prompts[0], max_new_tokens=2))
    rt.submit(GenerationRequest(rid=1, tokens=prompts[1], max_new_tokens=9))
    res = {r.rid: r for r in rt.drain()}
    assert res[0].decode_steps == 1           # its own steps, not 8
    assert res[1].decode_steps == 8
    # wall times are per-slot (jit compile noise makes ordering flaky on
    # cold caches, so only sanity-check they are populated per request)
    assert res[0].decode_s >= 0.0 and res[1].decode_s > 0.0
    assert res[0].prefill_s > 0.0 and res[1].prefill_s > 0.0


def test_sticky_dp_sessions_stay_on_their_group(dense_cfg):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    plan = ParallelPlan(service="toy", category=LAT, bs=2, dp=2, sticky=True)
    rt = ServiceRuntime(dense_cfg, params, plan)
    for i in range(6):
        rt.submit(GenerationRequest(rid=i, tokens=np.arange(1, 5,
                                                            dtype=np.int32),
                                    max_new_tokens=3, stream=1 + i % 2))
    res = rt.drain()
    assert len(res) == 6
    groups = {}
    for r in res:
        groups.setdefault(r.rid % 2, set()).add(r.group)
    assert all(len(g) == 1 for g in groups.values())   # session-sticky


def test_simulator_sync_mode_barriers_cost_goodput():
    """The simulator's sync discipline (batch barriers) must not beat its
    continuous discipline for the same latency workload."""
    import dataclasses as dc

    from repro.core.categories import Request, ServerSpec, ServiceSpec
    from repro.simulator.engine import SimConfig, run_comparison

    servers = [ServerSpec(sid=0, num_gpus=2)]
    services = {"chat": ServiceSpec("chat", flops_per_request=5e9,
                                    weights_bytes=1e8, vram_bytes=3e8,
                                    slo_latency_s=0.5)}
    rng = np.random.default_rng(0)
    events = []
    t = 0.0
    for i in range(60):
        t += float(rng.exponential(0.05))
        events.append((t, 0, Request(rid=i, service="chat", arrival_s=t,
                                     deadline_s=t + 0.5)))
    base = SimConfig(horizon_s=10.0, sync_interval_s=1.0)
    out = {}
    for mode in ("continuous", "sync"):
        cfg = dc.replace(base, serving_mode=mode)
        res = run_comparison(servers, services, events, ["EPARA"], cfg)
        out[mode] = res["EPARA"].goodput
    assert out["continuous"] >= out["sync"]