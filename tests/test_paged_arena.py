"""Paged KV arena data plane: allocator surface, block tables, paged↔dense
equivalence (property test), the retrace regression the fixed-capacity
design exists for, the paged decode kernel, and the satellite fixes
(sticky-session release, Composer protocol, occupancy-masked sampling,
simulator paged mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import DPGroupRouter, ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models import transformer as T
from repro.serving.arena import KVArena
from repro.serving.batching import BSComposer, Composer, MFComposer
from repro.serving.engine import (GenerationRequest, ServiceRuntime,
                                  StepStats)
from repro.serving.sampler import sample

from conftest import toy_config

LAT = TaskCategory(Sensitivity.LATENCY, False)


def _plan(bs=2, **kw):
    return ParallelPlan(service="t", category=LAT, bs=bs, **kw)


# ---------------------------------------------------------------------------
# arena allocator surface
# ---------------------------------------------------------------------------

def test_arena_classifies_leaves_and_sizes_pool(dense_cfg):
    a = KVArena(dense_cfg, T.init_cache, capacity=3, max_seq_len=40,
                block_size=8)
    assert a.slot_tokens == 40 and a.blocks_per_slot == 5
    assert a.pool_blocks == 15 and a.trash_block == 15
    assert len(a.pages) == 2          # k and v are paged
    assert len(a.state) == 0          # dense cfg has no fixed state leaves
    assert a.pages[0].shape == (dense_cfg.num_layers, 16, 8,
                                dense_cfg.num_kv_heads, dense_cfg.head_dim)
    assert a.token_bytes > 0


def test_arena_alloc_free_reuses_blocks(dense_cfg):
    a = KVArena(dense_cfg, T.init_cache, capacity=2, max_seq_len=32,
                block_size=8)
    s0 = a.alloc(20)                  # 3 blocks
    bt = a.block_tables()
    assert a.live == 1 and a.occupancy()[s0]
    assert (bt[s0] != a.trash_block).sum() == 3
    assert (bt[1 - s0] == a.trash_block).all()
    s1 = a.alloc(32)                  # 4 blocks
    assert not a.can_alloc(8)         # slots exhausted
    a.free(s0)
    assert a.can_alloc(24)
    s2 = a.alloc(24)
    assert s2 == s0                   # slot recycled through the free list
    assert a.live == 2
    a.free(s1), a.free(s2)
    assert a.live == 0
    assert (a.block_tables() == a.trash_block).all()
    assert len(a._free_blocks) == a.pool_blocks


def test_arena_rejects_over_budget(dense_cfg):
    a = KVArena(dense_cfg, T.init_cache, capacity=1, max_seq_len=16,
                block_size=8)
    with pytest.raises(ValueError):
        a.alloc(17)


def test_arena_write_then_gather_roundtrip(dense_cfg):
    """write_prefill scatters pages; dense_view through the block table
    reconstructs the request's cache row exactly."""
    a = KVArena(dense_cfg, T.init_cache, capacity=2, max_seq_len=16,
                block_size=8)
    prompt = jnp.asarray(np.arange(1, 6, dtype=np.int32)[None])
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    _, cache = T.prefill(params, dense_cfg, {"tokens": prompt},
                         cache_size=a.slot_tokens)
    slot = a.alloc(10)
    written = a.write_prefill(slot, cache, prompt_len=5)
    assert written == a.slot_bytes(5)
    dense = a.dense_view(a.pages, jnp.asarray(a.block_tables()))
    np.testing.assert_allclose(np.asarray(dense[0][:, slot]),
                               np.asarray(cache["k"][:, 0]), rtol=1e-6)
    assert int(a.lens[slot]) == 5


def test_arena_ssm_state_only():
    """State-space caches have no sequence axis: every leaf is per-slot
    state, the arena still gives fixed-shape decode."""
    from repro.models import ssm as S
    cfg = toy_config(family="ssm", ssm_state=4, ssm_headdim=16)
    a = KVArena(cfg, S.init_cache, capacity=2, max_seq_len=32, block_size=8)
    assert len(a.pages) == 0 and len(a.state) == 2
    assert a.state[0].shape[1] == 2


# ---------------------------------------------------------------------------
# paged engine behavior
# ---------------------------------------------------------------------------

def _runtime(cfg, params, *, impl="paged", bs=2, **kw):
    kw.setdefault("max_seq_len", 64)
    kw.setdefault("block_size", 8)
    return ServiceRuntime(cfg, params, _plan(bs=bs), kvcache_impl=impl,
                          **kw)


def _serve(rt, reqs):
    for i, (p, n) in enumerate(reqs):
        rt.submit(GenerationRequest(rid=i, tokens=p, max_new_tokens=n,
                                    stream=i))
    return {r.rid: list(r.tokens) for r in rt.drain()}


def test_retrace_regression_paged_compiles_once(dense_cfg):
    """Live batch size varying 1 -> capacity -> 1 must compile the fused
    decode step exactly once (the dense path retraces per batch shape)."""
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = _runtime(dense_cfg, params, bs=3)
    rt.submit(GenerationRequest(rid=0, tokens=np.arange(1, 6, dtype=np.int32),
                                max_new_tokens=10))
    rt.step(); rt.step()              # live = 1
    for i in (1, 2):                  # ramp to capacity mid-decode
        rt.submit(GenerationRequest(rid=i,
                                    tokens=np.arange(1, 4 + i, dtype=np.int32),
                                    max_new_tokens=2 + i))
    res = rt.drain()                  # ramps 3 -> ... -> 1 -> 0
    assert len(res) == 3
    assert rt.decode_traces == 1, rt.decode_traces
    assert rt.whole_cache_copies == 0


def test_chunked_prefill_bounded_compiles(dense_cfg):
    """Retrace regression: submitting prompts of MANY distinct lengths
    triggers at most ``len(chunk_buckets)`` prefill compiles and exactly 1
    decode compile — the unchunked path would trace one prefill per padded
    prompt length."""
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = _runtime(dense_cfg, params, bs=2)
    assert rt.chunked_prefill
    for i, plen in enumerate(range(1, 21)):     # 20 distinct prompt lengths
        rt.submit(GenerationRequest(
            rid=i, tokens=np.arange(1, plen + 1, dtype=np.int32),
            max_new_tokens=2))
    res = rt.drain()
    assert len(res) == 20
    assert rt.prefill_traces <= len(rt.chunk_buckets), \
        (rt.prefill_traces, rt.chunk_buckets)
    assert rt.decode_traces == 1, rt.decode_traces

    # the unchunked baseline really does retrace per prompt length
    rt2 = _runtime(dense_cfg, params, bs=2, chunked_prefill=False)
    for i, plen in enumerate(range(1, 21)):
        rt2.submit(GenerationRequest(
            rid=i, tokens=np.arange(1, plen + 1, dtype=np.int32),
            max_new_tokens=2))
    rt2.drain()
    assert rt2.prefill_traces > len(rt.chunk_buckets)


def test_dense_impl_retraces_on_batch_change(dense_cfg):
    """The documented cost the arena removes: the dense path compiles a
    new decode step per live batch shape."""
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = _runtime(dense_cfg, params, impl="dense", bs=3)
    reqs = [(np.arange(1, 6, dtype=np.int32), 6), (np.arange(1, 6, dtype=np.int32), 2),
            (np.arange(1, 6, dtype=np.int32), 4)]
    _serve(rt, reqs)
    assert rt.decode_traces > 1
    assert rt.whole_cache_copies > 0


def test_arena_block_exhaustion_requeues_until_free(dense_cfg):
    """A pool smaller than capacity x blocks_per_slot makes the block
    allocator real: admissions without blocks wait on the free list."""
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = ServiceRuntime(dense_cfg, params, _plan(bs=2),
                        kvcache_impl="paged", max_seq_len=32, block_size=8,
                        pool_blocks=5)     # 2 slots want up to 8 blocks
    reqs = [(np.arange(1, 9, dtype=np.int32), 16), (np.arange(1, 9, dtype=np.int32), 16),
            (np.arange(1, 9, dtype=np.int32), 16)]
    res = _serve(rt, reqs)                 # each needs 3 blocks
    assert sorted(res) == [0, 1, 2]        # all complete despite contention
    arena = rt.groups[0].arena
    # everything returned to circulation: blocks the prefix cache retains
    # on the idle LRU are still reclaimable, so nothing leaked
    assert arena.free_capacity == 5


def test_paged_rejects_request_over_slot_budget(dense_cfg):
    """Over-budget requests fail at submit() — raising mid-admission
    would drop the composed batch's other members."""
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = ServiceRuntime(dense_cfg, params, _plan(bs=1),
                        kvcache_impl="paged", max_seq_len=16, block_size=8)
    with pytest.raises(ValueError):
        rt.submit(GenerationRequest(rid=0,
                                    tokens=np.arange(1, 14, dtype=np.int32),
                                    max_new_tokens=8))
    # an in-budget neighbour is unaffected
    rt.submit(GenerationRequest(rid=1, tokens=np.arange(1, 5, dtype=np.int32),
                                max_new_tokens=2))
    assert [r.rid for r in rt.drain()] == [1]


def test_step_returns_stepstats_telemetry(dense_cfg):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = _runtime(dense_cfg, params, bs=2)
    rt.submit(GenerationRequest(rid=0, tokens=np.arange(1, 6, dtype=np.int32),
                                max_new_tokens=3))
    stats = rt.step()
    assert isinstance(stats, StepStats)
    assert stats.admitted == 1 and stats.in_flight == 1
    assert stats.whole_cache_copies == 0
    # chunked paged admission COPIES nothing (alloc is bookkeeping); the
    # chunk rows it writes are appends, counted separately so the
    # zero-copy gate measures what it claims
    assert stats.admission_copy_bytes == 0
    assert stats.chunk_write_bytes > 0
    out = rt.drain()
    assert len(out) == 1
    final = rt.step()
    assert final.results == [] and final.in_flight == 0
    assert final.queue_time_s >= 0.0


# ---------------------------------------------------------------------------
# paged <-> dense equivalence (property test; deterministic shim fallback)
# ---------------------------------------------------------------------------

_PROP_CFG = toy_config(num_layers=1, d_model=32, num_heads=2,
                       num_kv_heads=2, head_dim=16, d_ff=64)
_PROP_PARAMS = None


def _prop_params():
    global _PROP_PARAMS
    if _PROP_PARAMS is None:
        _PROP_PARAMS = T.init(jax.random.PRNGKey(7), _PROP_CFG)
    return _PROP_PARAMS


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), n_reqs=st.integers(1, 6),
       bs=st.integers(1, 3))
def test_random_schedules_match_dense_tokens_and_lens(seed, n_reqs, bs):
    """Random admit/evict/decode schedules (random prompt lengths, budgets
    and eos tokens) must produce identical greedy tokens and final lens
    under both kvcache_impls."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_reqs):
        plen = int(rng.integers(1, 9))
        n = int(rng.integers(1, 7))
        reqs.append((rng.integers(1, _PROP_CFG.vocab_size, plen)
                     .astype(np.int32), n))
    out = {}
    for impl in ("paged", "dense"):
        rt = ServiceRuntime(_PROP_CFG, _prop_params(), _plan(bs=bs),
                            kvcache_impl=impl, max_seq_len=32, block_size=8)
        out[impl] = _serve(rt, reqs)
    assert out["paged"] == out["dense"]
    lens = {rid: len(toks) for rid, toks in out["paged"].items()}
    assert lens == {i: min(len(out["dense"][i]), reqs[i][1])
                    for i in range(n_reqs)}


def test_moe_decode_rows_are_batch_independent():
    """Regression: decode-time MoE must route each slot's token in its own
    dispatch group.  A shared group makes tokens compete for expert
    capacity, so a request's output would depend on its batch neighbours —
    under the arena's fixed-capacity batch even on unoccupied slots'
    garbage rows."""
    from repro.models import moe as M
    cfg = toy_config(family="moe", num_experts=4, experts_per_token=2)
    params = M.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    reqs = [(rng.integers(1, cfg.vocab_size, 4 + i).astype(np.int32), 4)
            for i in range(3)]

    def direct(prompt, n):
        logits, cache = M.prefill(params, cfg,
                                  {"tokens": jnp.asarray(prompt[None])},
                                  cache_size=len(prompt) + n)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [int(tok[0])]
        for _ in range(n - 1):
            logits, cache = M.decode_step(params, cfg, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(int(tok[0]))
        return toks

    for impl in ("paged", "dense"):
        # one-shot prefill: this test pins decode-time routing semantics
        # against the raw model at TIGHT expert capacity, where chunked
        # prefill legitimately differs (capacity scales with the routing
        # group, and chunking changes the group from prompt to bucket —
        # tests/test_chunked_prefill.py covers chunked MoE parity at
        # non-binding capacity)
        rt = _runtime(cfg, params, impl=impl, bs=2, chunked_prefill=False)
        got = _serve(rt, reqs)
        for i, (p, n) in enumerate(reqs):
            assert got[i] == direct(p, n), (impl, i)


# ---------------------------------------------------------------------------
# paged decode kernel: Pallas (interpret) vs dense-gather ref
# ---------------------------------------------------------------------------

def test_paged_decode_attention_matches_gathered_ref(rng):
    from repro.kernels.decode_attention import (paged_decode_attention_pallas,
                                                paged_gather_ref)
    from repro.kernels.ref import decode_attention_ref
    B, Hq, Hkv, D, bs, nblk, P = 3, 4, 2, 16, 16, 3, 10
    q = jnp.asarray(rng.normal(size=(B, Hq, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P + 1, bs, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P + 1, bs, Hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.permutation(P)[:B * nblk]
                     .reshape(B, nblk).astype(np.int32))
    lens = jnp.asarray(np.array([5, 33, 48], np.int32))
    want = decode_attention_ref(q, paged_gather_ref(kp, bt),
                                paged_gather_ref(vp, bt), lens)
    got = paged_decode_attention_pallas(q, kp, vp, bt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ops_paged_decode_attention_ref_dispatch(rng):
    from repro.kernels import ops
    B, Hq, Hkv, D, bs, nblk, P = 2, 2, 2, 8, 8, 2, 6
    q = jnp.asarray(rng.normal(size=(B, Hq, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P + 1, bs, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P + 1, bs, Hkv, D)).astype(np.float32))
    bt = jnp.asarray(np.array([[0, 1], [2, 3]], np.int32))
    lens = jnp.asarray(np.array([7, 12], np.int32))
    out = ops.paged_decode_attention(q, kp, vp, bt, lens, impl="ref")
    assert out.shape == (B, Hq, D)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# satellites: sticky release, composer protocol, occupancy sampling, sim
# ---------------------------------------------------------------------------

def test_sticky_session_pins_released_on_final_evict(dense_cfg):
    """The DPGroupRouter leak fix: session->group entries disappear once a
    session has no queued or in-flight requests left, but survive while
    later requests of the session are still pending."""
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    plan = ParallelPlan(service="t", category=LAT, bs=2, dp=2, sticky=True)
    rt = ServiceRuntime(dense_cfg, params, plan, max_seq_len=64,
                        block_size=8)
    for i in range(6):
        rt.submit(GenerationRequest(rid=i, tokens=np.arange(1, 5, dtype=np.int32),
                                    max_new_tokens=3, stream=1 + i % 2))
    rt.step()
    assert rt.router.sessions() > 0       # pinned while in flight
    res = rt.drain()
    assert len(res) == 6
    assert rt.router.sessions() == 0      # fully released after drain
    groups = {}
    for r in res:
        groups.setdefault(r.rid % 2, set()).add(r.group)
    assert all(len(g) == 1 for g in groups.values())  # stickiness intact


def test_on_evict_hook_fires_per_request(dense_cfg):
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    seen = []
    rt = ServiceRuntime(dense_cfg, params, _plan(bs=2), max_seq_len=64,
                        block_size=8,
                        on_evict=lambda req, group: seen.append(req.rid))
    for i in range(3):
        rt.submit(GenerationRequest(rid=i, tokens=np.arange(1, 5, dtype=np.int32),
                                    max_new_tokens=2))
    rt.drain()
    assert sorted(seen) == [0, 1, 2]


def test_composers_share_one_protocol():
    bs = BSComposer(_plan(bs=4))
    mf = MFComposer(ParallelPlan(service="t",
                                 category=TaskCategory(Sensitivity.FREQUENCY,
                                                       False),
                                 bs=4, mf=2))
    assert isinstance(bs, Composer) and isinstance(mf, Composer)
    from repro.serving.batching import QueuedItem
    for c in (bs, mf):
        for s in (1, 2):
            for _ in range(2):
                c.add(QueuedItem(payload=0, stream=s))
        # the engine's single uniform call shape works on both families
        b = c.compose(limit=2, now=5.0, max_wait_s=0.0)
        assert b is not None and b.size == 2


def test_sampler_masks_occupancy_and_live():
    logits = jnp.array([[0.0, 5.0], [4.0, 0.0], [0.0, 3.0]])
    out = sample(logits, jax.random.PRNGKey(0),
                 live=jnp.array([True, True, False]),
                 occupancy=jnp.array([True, False, True]), fill_token=-1)
    assert list(np.asarray(out)) == [1, -1, -1]


def test_simulator_paged_mode_beats_dense_copy_overhead():
    import dataclasses as dc

    from repro.core.categories import Request, ServerSpec, ServiceSpec
    from repro.simulator.engine import SimConfig, run_comparison

    servers = [ServerSpec(sid=0, num_gpus=2)]
    services = {"chat": ServiceSpec("chat", flops_per_request=5e9,
                                    weights_bytes=1e8, vram_bytes=3e8,
                                    slo_latency_s=0.5)}
    rng = np.random.default_rng(0)
    events, t = [], 0.0
    for i in range(60):
        t += float(rng.exponential(0.05))
        events.append((t, 0, Request(rid=i, service="chat", arrival_s=t,
                                     deadline_s=t + 0.5)))
    base = SimConfig(horizon_s=10.0, sync_interval_s=1.0,
                     admission_copy_s=0.01)
    out = {}
    for mode in ("paged", "continuous", "sync"):
        cfg = dc.replace(base, serving_mode=mode)
        out[mode] = run_comparison(servers, services, events, ["EPARA"],
                                   cfg)["EPARA"].goodput
    assert out["paged"] >= out["continuous"] >= out["sync"]
    with pytest.raises(ValueError):
        run_comparison(servers, services, events, ["EPARA"],
                       dc.replace(base, serving_mode="bogus"))
