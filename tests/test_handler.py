"""Distributed request handler (§3.2): Fig. 6 decision ladder, Eq. 1
offload weighting, loop freedom, bounded offload counts — unit +
hypothesis property tests."""
import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.categories import Request, Sensitivity, ServiceSpec
from repro.core.handler import (Decision, Outcome, RequestHandler,
                                ServerView, ServiceState)

SVC = ServiceSpec(name="svc", flops_per_request=1e9, weights_bytes=1e8,
                  vram_bytes=2e8, slo_latency_s=1.0)


def _view(sid, *, p_hat=10.0, p_act=0.0, queue=0.0, age=0.1,
          available=True, cross=False, on_device=False):
    return ServerView(sid=sid, services={
        "svc": ServiceState(theoretical_goodput=p_hat, actual_goodput=p_act,
                            queue_time_s=queue, cross_server=cross,
                            on_device=on_device)},
        sync_age_s=age, available=available)


def _req(**kw):
    base = dict(rid=1, service="svc", arrival_s=0.0, deadline_s=1.0)
    base.update(kw)
    return Request(**base)


def test_timeout_first():
    h = RequestHandler(0)
    d = h.handle(_req(deadline_s=0.5), now=0.6, svc=SVC,
                 local=_view(0), peers={})
    assert d.outcome == Outcome.TIMEOUT


def test_local_first():
    h = RequestHandler(0)
    d = h.handle(_req(), now=0.1, svc=SVC, local=_view(0),
                 peers={1: _view(1)})
    assert d.outcome == Outcome.LOCAL


def test_local_priority_ladder():
    h = RequestHandler(0)
    # cross-server-parallel local outranks device, both beat offload
    d = h.handle(_req(), 0.1, SVC, _view(0, cross=True), {1: _view(1)})
    assert d.outcome == Outcome.LOCAL_CROSS
    d = h.handle(_req(), 0.1, SVC, _view(0, on_device=True), {1: _view(1)})
    assert d.outcome == Outcome.LOCAL_DEVICE


def test_saturated_local_offloads():
    h = RequestHandler(0, seed=1)
    local = _view(0, p_hat=10.0, p_act=10.0, queue=5.0)  # saturated
    d = h.handle(_req(), 0.1, SVC, local, {1: _view(1)})
    assert d.outcome == Outcome.OFFLOAD and d.destination == 1


def test_offload_count_bound():
    h = RequestHandler(0, max_offload_count=5)
    local = _view(0, p_hat=0.0, queue=99.0)
    req = _req(offload_count=5)
    d = h.handle(req, 0.1, SVC, local, {1: _view(1)})
    assert d.outcome == Outcome.OFFLOAD_EXCEEDED


def test_loop_freedom():
    h = RequestHandler(0, seed=0)
    local = _view(0, p_hat=0.0, queue=99.0)
    req = _req(path=(1, 2))
    d = h.handle(req, 0.1, SVC, local,
                 {1: _view(1), 2: _view(2), 3: _view(3)})
    assert d.outcome == Outcome.OFFLOAD and d.destination == 3


def test_queue_exclusion_rule():
    """Peers whose queued compute exceeds t_n + SLO are excluded (§3.2)."""
    h = RequestHandler(0, seed=0)
    local = _view(0, p_hat=0.0, queue=99.0)
    overdue = _view(1, queue=5.0, age=0.1)     # 5.0 > 0.1 + 1.0
    ok = _view(2, queue=0.2, age=0.1)
    d = h.handle(_req(), 0.1, SVC, local, {1: overdue, 2: ok})
    assert d.destination == 2


def test_insufficient_when_no_feasible_peer():
    h = RequestHandler(0)
    local = _view(0, p_hat=0.0, queue=99.0)
    d = h.handle(_req(), 0.1, SVC, local,
                 {1: _view(1, available=False), 2: _view(2, p_hat=0.0)})
    assert d.outcome == Outcome.INSUFFICIENT


def test_offload_probability_weighted_by_idle_goodput():
    """Eq. 1: destination frequency ∝ p̃ = p̂ − p."""
    h = RequestHandler(0, seed=42)
    local = _view(0, p_hat=0.0, queue=99.0)
    peers = {1: _view(1, p_hat=30.0, p_act=0.0),    # idle 30
             2: _view(2, p_hat=10.0, p_act=0.0)}    # idle 10
    counts = {1: 0, 2: 0}
    for _ in range(600):
        d = h.handle(_req(), 0.1, SVC, local, peers)
        counts[d.destination] += 1
    ratio = counts[1] / max(1, counts[2])
    assert 2.0 < ratio < 4.5   # expect ~3


def test_apply_offload_records_path():
    req = _req()
    fwd = RequestHandler.apply_offload(req, origin=7)
    assert fwd.path == (7,) and fwd.offload_count == 1
    assert req.path == ()   # original untouched


@settings(max_examples=60, deadline=None)
@given(
    p_hats=st.lists(st.floats(0, 100), min_size=1, max_size=6),
    p_acts=st.lists(st.floats(0, 100), min_size=1, max_size=6),
    queues=st.lists(st.floats(0, 10), min_size=1, max_size=6),
    offload_count=st.integers(0, 7),
    path=st.lists(st.integers(1, 6), max_size=4),
    now=st.floats(0, 2.0),
)
def test_handler_decision_always_valid(p_hats, p_acts, queues,
                                       offload_count, path, now):
    """Property: for arbitrary peer states the decision is well-formed —
    never offloads to itself, to a path member, to an unavailable or
    infeasible peer; respects the count bound and the timeout rule."""
    n = min(len(p_hats), len(p_acts), len(queues))
    peers = {i + 1: _view(i + 1, p_hat=p_hats[i], p_act=p_acts[i],
                          queue=queues[i]) for i in range(n)}
    h = RequestHandler(0, max_offload_count=5, seed=7)
    req = _req(offload_count=offload_count, path=tuple(path))
    local = _view(0, p_hat=0.0, queue=99.0)
    d = h.handle(req, now, SVC, local, peers)
    if now > req.deadline_s:
        assert d.outcome == Outcome.TIMEOUT
        return
    if d.outcome == Outcome.OFFLOAD:
        assert offload_count < 5
        dest = d.destination
        assert dest in peers and dest != 0 and dest not in path
        state = peers[dest].state_of("svc")
        assert state.idle_goodput > 0
        assert state.queue_time_s <= peers[dest].sync_age_s + SVC.slo_latency_s
    elif d.outcome == Outcome.OFFLOAD_EXCEEDED:
        assert offload_count >= 5
