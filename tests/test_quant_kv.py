"""Quantized paged KV: int8 block pools with per-row scales.

Covers the quantize→dequant math (error bound, exact ref↔Pallas-interpret
kernel parity through ``ops`` with ``QuantPages`` pools), end-to-end
tolerance of quantized-native serving against the unquantized oracle
across the attention families, prefix-cache share/COW/evict interleavings
over quantized blocks (no cross-slot corruption, identical tokens with
the cache on vs off), and the precision-knob plumbing (``ParallelPlan``
validation, category-derived defaults, engine and launcher rejection of
int8 on the dense cache impl).

``QUANT_KV_EXAMPLES`` scales the property-test budget (the CI hypothesis
job raises it on a fixed seed)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import ParallelPlan
from repro.core.categories import (KV_DTYPE_BY_SENSITIVITY, Sensitivity,
                                   TaskCategory)
from repro.kernels import ops
from repro.kernels.quant import QuantPages, dequantize, quantize
from repro.models.registry import model_api
from repro.serving.arena import KVArena
from repro.serving.engine import GenerationRequest, ServiceRuntime

from conftest import toy_config

LAT = TaskCategory(Sensitivity.LATENCY, False)
FREQ = TaskCategory(Sensitivity.FREQUENCY, False)
ATTENTION_FAMILIES = ("dense", "moe", "hybrid", "audio", "vlm")
_EXAMPLES = int(os.environ.get("QUANT_KV_EXAMPLES", "6"))


def _family_cfg(family):
    over = dict(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=97)
    if family == "moe":
        over.update(num_experts=4, experts_per_token=2,
                    moe_capacity_factor=8.0)
    elif family == "hybrid":
        over.update(ssm_state=4, ssm_headdim=16, attn_every=1)
    elif family == "audio":
        over.update(encoder_layers=1, encoder_len=8)
    elif family == "vlm":
        over.update(prefix_len=4)
    return toy_config(family=family, **over)


_CFGS = {f: _family_cfg(f) for f in ATTENTION_FAMILIES}
_PARAMS = {}


def _family_params(family):
    if family not in _PARAMS:
        _PARAMS[family] = model_api(_CFGS[family]).init(
            jax.random.PRNGKey(7), _CFGS[family])
    return _PARAMS[family]


def _requests(cfg, rng, n_reqs, max_new=4):
    reqs = []
    for i in range(n_reqs):
        plen = int(rng.integers(1, 13))
        extras = None
        if cfg.family in ("audio", "vlm"):
            dim = cfg.encoder_len if cfg.family == "audio" else cfg.prefix_len
            extras = {"embeddings": rng.normal(
                size=(dim, cfg.d_model)).astype(np.float32)}
        reqs.append(GenerationRequest(
            rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                       plen).astype(np.int32),
            max_new_tokens=max_new, extras=extras))
    return reqs


def _serve(cfg, params, reqs, kv_dtype, **kw):
    plan = ParallelPlan(service="t", category=LAT, bs=kw.pop("bs", 2),
                        kv_dtype=kv_dtype)
    rt = ServiceRuntime(cfg, params, plan, max_seq_len=48, block_size=8,
                        kvcache_impl="paged", **kw)
    for r in reqs:
        rt.submit(r)
    return rt, {r.rid: list(r.tokens) for r in rt.drain()}


# ---------------------------------------------------------------------------
# quantize / dequantize math
# ---------------------------------------------------------------------------

@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2 ** 16), rows=st.integers(1, 16),
       d=st.sampled_from((4, 16, 64)), scale=st.sampled_from((0.1, 1.0, 8.0)))
def test_quantize_roundtrip_error_is_bounded_by_half_step(seed, rows, d,
                                                          scale):
    """Symmetric per-row int8: every element's roundtrip error is at most
    half a quantization step (rowmax/127/2) plus float fuzz, and the zero
    row survives the EPS floor without NaN/Inf."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(rows, d)) * scale).astype(np.float32)
    x[0] = 0.0
    q, s = quantize(jnp.asarray(x))
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == (rows,)
    back = np.asarray(dequantize(q, s))
    step = np.maximum(np.abs(x).max(axis=-1, keepdims=True), 1e-8) / 127.0
    assert np.all(np.isfinite(back))
    assert np.all(np.abs(back - x) < 0.5 * step + 1e-6)


def test_quant_pages_is_a_transparent_pytree():
    """QuantPages flattens to (values, scales) so jit/scan/donation see
    two leaves, while shape/dtype proxy the value array for the families'
    shape-reading call sites."""
    qp = QuantPages(*quantize(jnp.ones((3, 4, 2, 8))))
    leaves, treedef = jax.tree.flatten(qp)
    assert len(leaves) == 2
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, QuantPages)
    assert qp.shape == (3, 4, 2, 8) and qp.ndim == 4
    assert qp.dtype == jnp.int8


# ---------------------------------------------------------------------------
# kernel parity: quantized ref vs Pallas interpret through ops dispatch
# ---------------------------------------------------------------------------

def _paged_fixture(seed, B=2, blocks=4, bs=8, Hq=4, Hkv=2, D=16):
    rng = np.random.default_rng(seed)
    P = B * blocks + 1                                    # + trash page
    kp = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.float32)
    bt = jnp.arange(B * blocks, dtype=jnp.int32).reshape(B, blocks)
    lens = jnp.asarray(rng.integers(1, blocks * bs + 1, B), jnp.int32)
    return kp, vp, bt, lens, (Hq, D)


@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2 ** 16))
def test_quant_paged_decode_interpret_matches_ref(seed):
    """The fused dequant decode kernel (interpret mode) must reproduce the
    ref path's gather→dequant→oracle to float fuzz: both consume the SAME
    int8 values + f32 scales, so any gap is kernel logic, not rounding."""
    kp, vp, bt, lens, (Hq, D) = _paged_fixture(seed)
    kq, vq = QuantPages(*quantize(kp)), QuantPages(*quantize(vp))
    q = jnp.asarray(np.random.default_rng(seed + 1).normal(
        size=(bt.shape[0], Hq, D)), jnp.float32)
    out_ref = ops.paged_decode_attention(q, kq, vq, bt, lens, impl="ref")
    out_pl = ops.paged_decode_attention(q, kq, vq, bt, lens,
                                        impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2 ** 16), chunk=st.sampled_from((1, 4, 8)))
def test_quant_paged_chunk_interpret_matches_ref(seed, chunk):
    """Quantized chunked-prefill: same exact-parity contract as decode,
    with per-slot start offsets and causal masking inside the chunk."""
    kp, vp, bt, lens, (Hq, D) = _paged_fixture(seed)
    kq, vq = QuantPages(*quantize(kp)), QuantPages(*quantize(vp))
    B = bt.shape[0]
    rng = np.random.default_rng(seed + 2)
    start = jnp.asarray([int(l) for l in np.minimum(
        np.asarray(lens), bt.shape[1] * kp.shape[1] - chunk)], jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, chunk, Hq, D)), jnp.float32)
    cl = jnp.full((B,), chunk, jnp.int32)
    out_ref = ops.paged_chunk_attention(q, kq, vq, bt, start, cl,
                                        impl="ref")
    out_pl = ops.paged_chunk_attention(q, kq, vq, bt, start, cl,
                                       impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2 ** 16))
def test_quantized_decode_tracks_unquantized_oracle(seed):
    """int8 pools vs the same pools unquantized: attention output drifts
    only by the quantization noise (unit-normal K/V → well under 5e-2),
    never structurally (wrong rows / dropped blocks would blow this up)."""
    kp, vp, bt, lens, (Hq, D) = _paged_fixture(seed)
    kq, vq = QuantPages(*quantize(kp)), QuantPages(*quantize(vp))
    q = jnp.asarray(np.random.default_rng(seed + 3).normal(
        size=(bt.shape[0], Hq, D)), jnp.float32)
    exact = ops.paged_decode_attention(q, kp, vp, bt, lens, impl="ref")
    approx = ops.paged_decode_attention(q, kq, vq, bt, lens, impl="ref")
    np.testing.assert_allclose(np.asarray(approx), np.asarray(exact),
                               atol=5e-2)


# ---------------------------------------------------------------------------
# family-level parity: quantized native serving vs bf16 within tolerance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ATTENTION_FAMILIES)
def test_families_quantized_serving_tracks_native_tokens(family):
    """Serving an identical request wave with ``kv_dtype='int8'`` must
    produce the same response lengths and near-identical greedy tokens as
    the native-precision run (small drift may flip a late token; gross
    disagreement means the quantized write or read path is broken) — with
    still exactly one decode compile."""
    cfg, params = _CFGS[family], _family_params(family)
    rng = np.random.default_rng(13)
    reqs = _requests(cfg, rng, n_reqs=4)
    rt_q, toks_q = _serve(cfg, params, reqs, kv_dtype="int8")
    _, toks_n = _serve(cfg, params, reqs, kv_dtype="bf16")
    assert rt_q.kv_dtype == "int8"
    assert rt_q.decode_traces <= 1
    assert set(toks_q) == set(toks_n)
    agree = total = 0
    for rid, seq in toks_n.items():
        assert len(toks_q[rid]) == len(seq)
        agree += sum(a == b for a, b in zip(toks_q[rid], seq))
        total += len(seq)
    assert agree >= 0.9 * total, (family, toks_q, toks_n)


# ---------------------------------------------------------------------------
# prefix cache over quantized blocks: share / COW / evict interleavings
# ---------------------------------------------------------------------------

_QCFG = toy_config(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                   head_dim=16, d_ff=64)
_QPARAMS = None


def _qparams():
    global _QPARAMS
    if _QPARAMS is None:
        _QPARAMS = model_api(_QCFG).init(jax.random.PRNGKey(7), _QCFG)
    return _QPARAMS


def _qarena(capacity=3, **kw):
    return KVArena(_QCFG, model_api(_QCFG).init_cache, capacity=capacity,
                   max_seq_len=32, block_size=8, kv_dtype="int8", **kw)


def test_quantized_share_cow_evict_interleaving_preserves_other_slots():
    """Over int8 pools: share a 2-block prefix, COW-fork the sharer, write
    divergent rows into the fork, evict the source — the surviving chain
    still dequantizes to the original prefix bit-for-bit (COW clones the
    int8 values AND their scales), and every block returns to the free
    list at the end."""
    api = model_api(_QCFG)
    a = _qarena()
    assert isinstance(a.pages[0], QuantPages)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, _QCFG.vocab_size, 16).astype(np.int32)
    _, cache = api.prefill(_qparams(), _QCFG, {"tokens": prompt[None]},
                           cache_size=a.slot_tokens)
    sA = a.alloc(24)
    a.write_prefill(sA, cache, prompt_len=16)
    rowA = a.block_tables()[sA][:2]
    want = np.asarray(
        a.dense_view(a.pages, a.block_tables()[sA][None])[0])[:, :, :16]
    # share, then COW-fork block 0 of the sharer
    sB = a.alloc(24, shared=list(rowA))
    assert all(a.block_ref(int(b)) == 2 for b in rowA)
    assert a.cow_block(sB, 0)
    rowB_full = a.block_tables()[sB][None]
    got_fork = np.asarray(a.dense_view(a.pages, rowB_full)[0])[:, :, :8]
    np.testing.assert_allclose(got_fork, want[:, :, :8])   # exact clone
    # divergent writes into the fork must not leak into A's chain
    dense_new = [jnp.ones((leaf.shape[0], 1, a.slot_tokens,
                           *leaf.shape[3:]), jnp.float32)
                 for leaf in (cache["k"], cache["v"])]
    a.pages = a.append_rows(a.pages, dense_new, jnp.zeros((1,), jnp.int32),
                            jnp.ones((1,), bool), jnp.asarray(rowB_full))
    rowA_full = a.block_tables()[sA][None]
    va = np.asarray(a.dense_view(a.pages, rowA_full)[0])[:, :, :16]
    np.testing.assert_allclose(va, want)
    # evict the source: the fork's surviving shared block keeps the data
    a.free(sA)
    assert a.block_ref(int(rowA[1])) == 1
    vb = np.asarray(a.dense_view(a.pages, a.block_tables()[sB][None])[0])
    np.testing.assert_allclose(vb[:, :, 8:16], want[:, :, 8:16])
    a.free(sB)
    assert len(a._free_blocks) == a.pool_blocks


def test_quantized_prefix_cache_tokens_match_cache_off_run():
    """Engine-level: with int8 pools, warm template + sharing wave +
    mid-block divergence (forcing COW on a quantized block) produce
    IDENTICAL tokens to a cache-off int8 run, with real hit/COW
    telemetry — sharing reuses int8 blocks, it never re-quantizes."""
    rng = np.random.default_rng(3)
    base = rng.integers(1, _QCFG.vocab_size, 20).astype(np.int32)

    def run(**kw):
        plan = ParallelPlan(service="t", category=LAT, bs=2,
                            kv_dtype="int8")
        rt = ServiceRuntime(_QCFG, _qparams(), plan, max_seq_len=64,
                            block_size=8, kvcache_impl="paged", **kw)
        reqs = [GenerationRequest(rid=0, tokens=base, max_new_tokens=3)]
        for r in reqs:
            rt.submit(r)
        toks = {r.rid: tuple(r.tokens) for r in rt.drain()}
        wave = [GenerationRequest(
            rid=1, tokens=np.concatenate([base[:18], [88, 87]])
            .astype(np.int32), max_new_tokens=3),
            GenerationRequest(rid=2, tokens=base.copy(), max_new_tokens=3)]
        for r in wave:
            rt.submit(r)
        toks.update({r.rid: tuple(r.tokens) for r in rt.drain()})
        return rt, toks

    rt_on, toks_on = run()
    rt_off, toks_off = run(prefix_cache=0)
    assert rt_on.kv_dtype == "int8" and rt_off.kv_dtype == "int8"
    assert toks_on == toks_off
    assert rt_on.prefix_hits >= 1
    assert rt_on.prefix_cow_copies >= 1
    assert rt_on.prefill_tokens_computed < rt_off.prefill_tokens_computed


# ---------------------------------------------------------------------------
# precision-knob plumbing: plan validation, category defaults, launcher
# ---------------------------------------------------------------------------

def test_parallel_plan_rejects_unknown_kv_dtype():
    with pytest.raises(ValueError, match="kv_dtype"):
        ParallelPlan(service="t", category=LAT, bs=1, kv_dtype="fp8")
    with pytest.raises(ValueError, match="kv_dtype"):
        ParallelPlan(service="t", category=LAT, bs=1, kv_dtype="float16")


def test_resolved_kv_dtype_follows_category_then_override():
    assert ParallelPlan(service="t", category=LAT,
                        bs=1).resolved_kv_dtype() == "bf16"
    assert ParallelPlan(service="t", category=FREQ,
                        bs=1).resolved_kv_dtype() == "int8"
    assert ParallelPlan(service="t", category=FREQ, bs=1,
                        kv_dtype="bf16").resolved_kv_dtype() == "bf16"
    assert ParallelPlan(service="t", category=LAT, bs=1,
                        kv_dtype="int8").resolved_kv_dtype() == "int8"
    assert set(KV_DTYPE_BY_SENSITIVITY) == {Sensitivity.LATENCY,
                                            Sensitivity.FREQUENCY}


def test_engine_rejects_explicit_int8_on_dense_cache():
    plan = ParallelPlan(service="t", category=LAT, bs=1, kv_dtype="int8")
    with pytest.raises(ValueError, match="paged"):
        ServiceRuntime(_QCFG, _qparams(), plan, max_seq_len=32,
                       block_size=8, kvcache_impl="dense")


def test_engine_category_int8_degrades_to_native_on_dense_cache():
    """A frequency plan's DERIVED int8 silently stays native on the dense
    impl (there are no page pools to quantize) — only the explicit
    override is an error."""
    plan = ParallelPlan(service="t", category=FREQ, bs=1)
    rt = ServiceRuntime(_QCFG, _qparams(), plan, max_seq_len=32,
                        block_size=8, kvcache_impl="dense")
    assert rt.kv_dtype == "bf16"


def test_arena_rejects_unknown_kv_dtype():
    with pytest.raises(ValueError, match="kv_dtype"):
        KVArena(_QCFG, model_api(_QCFG).init_cache, capacity=2,
                max_seq_len=32, block_size=8, kv_dtype="fp8")


def test_serve_launcher_rejects_bad_kv_dtype_flags():
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--archs", "codeqwen1.5-7b", "--requests", "1",
                    "--kv-dtype", "fp8"])
    with pytest.raises(SystemExit):
        serve.main(["--archs", "codeqwen1.5-7b", "--requests", "1",
                    "--kv-dtype", "int8", "--kvcache-impl", "dense"])
