"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracles,
swept over shapes/dtypes, plus flash-backward gradient checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gemm import grouped_matmul_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

TOL = dict(rtol=2e-3, atol=2e-3)


def _qkv(key, B, Lq, Lk, Hq, Hkv, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Lq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Lk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Lk, Hkv, D), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Lq,Lk,Hq,Hkv,D", [
    (1, 64, 64, 4, 4, 32),      # MHA square
    (2, 40, 72, 8, 2, 16),      # GQA ragged
    (1, 16, 128, 4, 1, 64),     # MQA, Lk > Lq
])
def test_flash_vs_exact(dtype, B, Lq, Lk, Hq, Hkv, D):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, Lq, Lk, Hq, Hkv, D, dtype)
    want = ref.mha_exact(q, k, v, causal=True, q_offset=Lk - Lq)
    got = flash_attention_pallas(q, k, v, causal=True, q_offset=Lk - Lq,
                                 q_block=16, k_block=16, interpret=True)
    tol = TOL if dtype == jnp.float32 else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("kwargs", [
    dict(causal=True, window=16),
    dict(causal=False),
    dict(causal=True, prefix_len=8),
    dict(causal=True, kv_len=50),
    dict(causal=True, window=8, prefix_len=4),
])
def test_flash_masks(kwargs):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 48, 64, 4, 2, 32, jnp.float32)
    want = ref.mha_exact(q, k, v, q_offset=16, **kwargs)
    got = flash_attention_pallas(q, k, v, q_offset=16, q_block=16,
                                 k_block=16, interpret=True, **kwargs)
    np.testing.assert_allclose(got, want, **TOL)


def test_flash_ref_matches_exact_large_blocks():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 100, 100, 4, 4, 16,
                   jnp.float32)
    want = ref.mha_exact(q, k, v)
    got = ref.flash_attention_ref(q, k, v, q_chunk=33, k_chunk=17)
    np.testing.assert_allclose(got, want, **TOL)


def test_flash_custom_vjp_grads():
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 24, 24, 4, 2, 16, jnp.float32)

    def f_exact(q, k, v):
        return (ref.mha_exact(q, k, v, causal=True, window=9) ** 2).sum()

    def f_flash(q, k, v):
        return (ops.flash_attention(q, k, v, causal=True, window=9,
                                    impl="ref") ** 2).sum()

    g_want = jax.grad(f_exact, argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_got, g_want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(lq=st.integers(4, 40), lk=st.integers(4, 40),
       window=st.one_of(st.none(), st.integers(1, 48)),
       group=st.sampled_from([1, 2, 4]))
def test_flash_property_mask_semantics(lq, lk, window, group):
    """Property: blocked flash == exact attention for arbitrary sizes,
    windows, and GQA group factors (the invariant each Pallas kernel must
    preserve)."""
    Hkv, D = 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(lq * 131 + lk), 1, lq, lk,
                   Hkv * group, Hkv, D, jnp.float32)
    off = max(0, lk - lq)
    want = ref.mha_exact(q, k, v, causal=True, window=window, q_offset=off)
    got = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                  q_offset=off, q_chunk=8, k_chunk=8)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 16])
def test_decode_vs_ref(dtype, window):
    B, S, Hq, Hkv, D = 3, 96, 8, 2, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Hq, D), dtype)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D), dtype)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), dtype)
    lens = jnp.array([96, 40, 7])
    want = ref.decode_attention_ref(q, kc, vc, lens, window=window)
    got = decode_attention_pallas(q, kc, vc, lens, window=window,
                                  k_block=16, interpret=True)
    tol = TOL if dtype == jnp.float32 else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_decode_matches_exact_single():
    """Decode vs a 1-query exact attention at each valid length."""
    B, S, Hq, Hkv, D = 1, 33, 4, 4, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Hq, D))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))
    for L in (1, 17, 33):
        got = ref.decode_attention_ref(q, kc, vc, L)
        want = ref.mha_exact(q[:, None], kc[:, :L], vc[:, :L],
                             causal=False)[:, 0]
        np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def _ssd_inputs(key, Bb, L, H, P, G, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bb, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bb, L, G, N))
    C = jax.random.normal(ks[4], (Bb, L, G, N))
    D = jnp.ones((H,))
    return x, dt, A, B, C, D


@pytest.mark.parametrize("chunk", [8, 32, 128])
@pytest.mark.parametrize("L", [17, 64])
def test_ssd_chunked_vs_exact(chunk, L):
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(0), 2, L, 4, 8, 2, 4)
    y1, h1 = ref.ssd_exact(x, dt, A, B, C, D)
    y2, h2 = ref.ssd_chunked_ref(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(y2, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, h1, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("L,chunk", [(64, 16), (50, 16)])
def test_ssd_pallas_vs_ref(L, chunk):
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(1), 2, L, 4, 16, 2, 8)
    h0 = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 16, 8))
    y1, h1 = ref.ssd_chunked_ref(x, dt, A, B, C, D, chunk=chunk,
                                 initial_state=h0)
    y2, h2 = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                             initial_state=h0, interpret=True)
    np.testing.assert_allclose(y2, y1, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(h2, h1, rtol=1e-3, atol=1e-3)


def test_ssd_decode_step_consistency():
    """Chunked prefill then recurrent steps == full chunked run."""
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(2), 1, 20, 2, 8, 1, 4)
    y_all, h_all = ref.ssd_chunked_ref(x, dt, A, B, C, D, chunk=8)
    y_pre, h = ref.ssd_chunked_ref(x[:, :15], dt[:, :15], A, B[:, :15],
                                   C[:, :15], D, chunk=8)
    for t in range(15, 20):
        y_t, h = ref.ssd_decode_step_ref(h, x[:, t], dt[:, t], A, B[:, t],
                                         C[:, t], D)
        np.testing.assert_allclose(y_t, y_all[:, t], rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(L=st.integers(2, 40), chunk=st.sampled_from([4, 8, 16]),
       G=st.sampled_from([1, 2]))
def test_ssd_property_chunk_invariance(L, chunk, G):
    """Property: the output is invariant to the chunk size (the kernel's
    tiling must not change the math)."""
    H = 2 * G
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(L * 7 + chunk),
                                    1, L, H, 4, G, 4)
    y1, h1 = ref.ssd_chunked_ref(x, dt, A, B, C, D, chunk=chunk)
    y2, h2 = ref.ssd_exact(x, dt, A, B, C, D)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,K,N", [(4, 50, 70, 33), (2, 128, 64, 128),
                                     (8, 10, 200, 16)])
def test_grouped_matmul(dtype, E, C, K, N):
    lhs = jax.random.normal(jax.random.PRNGKey(0), (E, C, K), dtype)
    rhs = jax.random.normal(jax.random.PRNGKey(1), (E, K, N), dtype)
    want = ref.grouped_matmul_ref(lhs, rhs)
    got = grouped_matmul_pallas(lhs, rhs, block_c=16, block_n=16,
                                block_k=32, interpret=True)
    tol = dict(rtol=1e-4, atol=1e-3) if dtype == jnp.float32 \
        else dict(rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_ops_dispatch_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "bogus")
    with pytest.raises(ValueError):
        ops.default_impl()
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas_interpret")
    assert ops.default_impl() == "pallas_interpret"


# ---------------------------------------------------------------------------
# flash attention backward kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=True, window=9),
    dict(causal=False),
    dict(causal=True, prefix_len=7),
])
def test_flash_bwd_pallas_vs_ref(kwargs):
    from repro.kernels.flash_attention_bwd import flash_attention_bwd_pallas
    B, Lq, Lk, Hq, Hkv, D = 2, 40, 56, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, Lq, Hq, D))
    k = jax.random.normal(ks[1], (B, Lk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Lk, Hkv, D))
    do = jax.random.normal(ks[3], (B, Lq, Hq, D))
    out, lse = ref.flash_attention_fwd_ref(q, k, v, **kwargs)
    want = ref.flash_attention_bwd_ref(q, k, v, out, lse, do, **kwargs)
    got = flash_attention_bwd_pallas(q, k, v, out, lse, do, q_block=16,
                                     k_block=16, interpret=True, **kwargs)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_flash_pallas_lse_matches_ref():
    from repro.kernels.flash_attention import flash_attention_pallas
    q, k, v = _qkv(jax.random.PRNGKey(5), 2, 33, 48, 4, 2, 16, jnp.float32)
    o1, l1 = ref.flash_attention_fwd_ref(q, k, v, causal=True, window=11)
    o2, l2 = flash_attention_pallas(q, k, v, causal=True, window=11,
                                    q_block=16, k_block=16,
                                    return_lse=True, interpret=True)
    np.testing.assert_allclose(o2, o1, **TOL)
    np.testing.assert_allclose(l2, l1, rtol=1e-5, atol=1e-5)


def test_full_pallas_train_grads_vs_exact():
    """End-to-end: pallas fwd (with lse) + pallas bwd under jax.grad
    matches autodiff through the exact oracle."""
    q, k, v = _qkv(jax.random.PRNGKey(7), 1, 28, 28, 4, 2, 16, jnp.float32)

    def f(q, k, v):
        return (ops.flash_attention(q, k, v, causal=True, window=11,
                                    impl="pallas_interpret") ** 2).sum()

    def fe(q, k, v):
        return (ref.mha_exact(q, k, v, causal=True, window=11) ** 2).sum()

    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(fe, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
