"""Zero-gather paged decode: the attention families' paged-NATIVE
decode/chunk steps must be bit-identical to the dense-gather oracle (and
the dense kvcache impl) across all six families, the compiled fused step
must contain no full-pool dense KV materialization (HLO shape + XLA
cost-analysis regression), batched COW must coalesce a wave's copies into
one dispatch, and the launcher's pjit builder must produce the same
tokens under a service mesh.

``PAGED_NATIVE_EXAMPLES`` scales the hypothesis example budget (the CI
hypothesis job raises it on a fixed seed).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import ParallelPlan
from repro.core.categories import Sensitivity, TaskCategory
from repro.models.registry import model_api
from repro.serving.engine import GenerationRequest, ServiceRuntime

from conftest import toy_config

LAT = TaskCategory(Sensitivity.LATENCY, False)
FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
ATTENTION_FAMILIES = ("dense", "moe", "hybrid", "audio", "vlm")
_EXAMPLES = int(os.environ.get("PAGED_NATIVE_EXAMPLES", "6"))


def _family_cfg(family):
    over = dict(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=97)
    if family == "moe":
        over.update(num_experts=4, experts_per_token=2,
                    moe_capacity_factor=8.0)
    elif family in ("ssm", "hybrid"):
        over.update(ssm_state=4, ssm_headdim=16)
        if family == "hybrid":
            over.update(attn_every=1)
    elif family == "audio":
        over.update(encoder_layers=1, encoder_len=8)
    elif family == "vlm":
        over.update(prefix_len=4)
    return toy_config(family=family, **over)


_CFGS = {f: _family_cfg(f) for f in FAMILIES}
_PARAMS = {}


def _family_params(family):
    if family not in _PARAMS:
        _PARAMS[family] = model_api(_CFGS[family]).init(
            jax.random.PRNGKey(7), _CFGS[family])
    return _PARAMS[family]


def _requests(cfg, rng, n_reqs):
    reqs = []
    for i in range(n_reqs):
        plen = int(rng.integers(1, 13))
        n = int(rng.integers(1, 5))
        extras = None
        if cfg.family in ("audio", "vlm"):
            dim = cfg.encoder_len if cfg.family == "audio" else cfg.prefix_len
            extras = {"embeddings": rng.normal(
                size=(dim, cfg.d_model)).astype(np.float32)}
        reqs.append(GenerationRequest(
            rid=i, tokens=rng.integers(1, cfg.vocab_size,
                                       plen).astype(np.int32),
            max_new_tokens=n, extras=extras))
    return reqs


def _serve(cfg, params, reqs, **kw):
    rt = ServiceRuntime(cfg, params, ParallelPlan(service="t", category=LAT,
                                                  bs=kw.pop("bs", 2)),
                        max_seq_len=48, block_size=8, **kw)
    for r in reqs:
        rt.submit(r)
    return rt, {r.rid: list(r.tokens) for r in rt.drain()}


# ---------------------------------------------------------------------------
# greedy-token parity: paged-native vs dense-gather oracle vs dense impl
# ---------------------------------------------------------------------------

@settings(max_examples=_EXAMPLES, deadline=None, derandomize=True)
@given(family=st.sampled_from(FAMILIES), seed=st.integers(0, 2 ** 16),
       bs=st.integers(1, 3))
def test_paged_native_matches_oracle_across_families(family, seed, bs):
    """Random admit/chunk/evict schedules must yield IDENTICAL greedy
    tokens whether attention reads K/V in place through the block tables
    (paged-native), through the dense-gather oracle step
    (``paged_native=False``), or via the dense kvcache impl — for every
    model family (pure-SSM families exercise the unchanged state path)."""
    cfg, params = _CFGS[family], _family_params(family)
    rng = np.random.default_rng(seed)
    reqs = _requests(cfg, rng, n_reqs=4)
    rt_n, native = _serve(cfg, params, reqs, bs=bs, kvcache_impl="paged")
    _, oracle = _serve(cfg, params, reqs, bs=bs, kvcache_impl="paged",
                       paged_native=False)
    _, dense = _serve(cfg, params, reqs, bs=bs, kvcache_impl="dense")
    assert native == oracle, (family, seed)
    assert native == dense, (family, seed)
    assert rt_n.paged_native == (family in ATTENTION_FAMILIES)
    assert rt_n.decode_traces <= 1           # still one compile per service


@pytest.mark.parametrize("family", ATTENTION_FAMILIES)
def test_decode_step_paged_chains_like_decode_step(family):
    """Model-level harness (no engine): after identical prefills, chaining
    ``decode_step_paged`` over the arena pools produces the same greedy
    tokens as ``decode_step`` over the dense cache."""
    from repro.serving.arena import KVArena

    cfg, params = _CFGS[family], _family_params(family)
    api = model_api(cfg)
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, cfg.vocab_size, 7).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompt[None])}
    if cfg.family in ("audio", "vlm"):
        dim = cfg.encoder_len if cfg.family == "audio" else cfg.prefix_len
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(1, dim, cfg.d_model)), jnp.float32)
    extra = cfg.prefix_len if cfg.family == "vlm" else 0

    arena = KVArena(cfg, api.init_cache, capacity=2, max_seq_len=32,
                    block_size=8)
    logits, cache = api.prefill(params, cfg, batch,
                                cache_size=arena.slot_tokens - extra)
    slot = arena.alloc(arena.slot_tokens)
    arena.write_prefill(slot, cache, prompt_len=len(prompt) + extra)
    # dense reference cache: same prefill, per-slot lens
    dense_cache = jax.tree.map(lambda x: x, cache)

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    tok_paged = tok
    live = jnp.asarray(np.arange(arena.capacity) == slot)
    for _ in range(4):
        l1, dense_cache = api.decode_step(params, cfg, tok, dense_cache)
        tokens = jnp.zeros((arena.capacity,), jnp.int32
                           ).at[slot].set(tok_paged[0])
        paged = arena.assemble(arena.pages, arena.state, arena.lens)
        l2, new_cache = api.decode_step_paged(
            params, cfg, tokens, paged, arena.device_block_tables(), live,
            block_size=arena.block_size)
        new_pages, new_state = arena.disassemble(new_cache)
        arena.pages = new_pages
        arena.state = arena.merge_state(arena.state, new_state, live)
        arena.lens = jnp.where(live, arena.lens + 1, arena.lens)
        tok = jnp.argmax(l1, -1).astype(jnp.int32)
        tok_paged = jnp.argmax(l2[slot][None], -1).astype(jnp.int32)
        assert int(tok[0]) == int(tok_paged[0]), family


# ---------------------------------------------------------------------------
# HLO regression: no full-pool dense KV materialization on the hot path
# ---------------------------------------------------------------------------

def _decode_artifacts(cfg, params, *, native, max_seq_len=256, bs=4):
    rt = ServiceRuntime(cfg, params,
                        ParallelPlan(service="t", category=LAT, bs=bs),
                        kvcache_impl="paged", max_seq_len=max_seq_len,
                        block_size=32, paged_native=native)
    rt.submit(GenerationRequest(rid=0,
                                tokens=np.arange(1, 6, dtype=np.int32),
                                max_new_tokens=2))
    rt.drain()
    arena = rt.groups[0].arena
    lowered = jax.jit(rt._paged_decode_pure(arena)).lower(
        rt.params, jnp.zeros((arena.capacity,), jnp.int32),
        arena.pages, arena.state, arena.lens,
        jnp.ones((arena.capacity,), bool), arena.device_block_tables())
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return rt, arena, compiled.as_text(), dict(cost)


def test_paged_decode_step_contains_no_full_pool_gather(dense_cfg):
    """The compiled paged-native decode step must never materialize the
    ``(layers, capacity, slot_tokens, Hkv, D)`` dense KV view the old
    gather path round-tripped per token — asserted on the optimized HLO
    (the full-view shape is absent) AND on XLA's cost analysis (bytes
    accessed strictly below the dense-gather oracle's; on TPU the Pallas
    kernels additionally skip past-``len`` blocks, so real traffic scales
    with live tokens)."""
    params = model_api(dense_cfg).init(jax.random.PRNGKey(0), dense_cfg)
    rt_n, arena, hlo_n, cost_n = _decode_artifacts(dense_cfg, params,
                                                   native=True)
    rt_o, _, hlo_o, cost_o = _decode_artifacts(dense_cfg, params,
                                               native=False)
    full_view = (f"[{dense_cfg.num_layers},{arena.capacity},"
                 f"{arena.slot_tokens},{dense_cfg.num_kv_heads},"
                 f"{dense_cfg.head_dim}]")
    assert full_view not in hlo_n, \
        f"paged-native decode step materializes a full dense view " \
        f"{full_view}"
    assert full_view in hlo_o        # the oracle really is the old path
    assert cost_n["bytes accessed"] < cost_o["bytes accessed"]


def test_paged_decode_bytes_grow_slower_than_pool(dense_cfg):
    """Doubling the per-slot token budget grows the dense-gather oracle's
    bytes-accessed by the full pool delta several times over (gather +
    re-scatter round trips); the paged-native step's growth must stay
    well below the oracle's — the per-token bandwidth win the tentpole
    exists for."""
    params = model_api(dense_cfg).init(jax.random.PRNGKey(0), dense_cfg)

    def bytes_at(native, msl):
        _, _, _, cost = _decode_artifacts(dense_cfg, params, native=native,
                                          max_seq_len=msl)
        return cost["bytes accessed"]

    d_native = bytes_at(True, 512) - bytes_at(True, 128)
    d_oracle = bytes_at(False, 512) - bytes_at(False, 128)
    assert d_native < 0.75 * d_oracle, (d_native, d_oracle)


def test_decode_cost_analysis_keeps_compile_counters(dense_cfg):
    params = model_api(dense_cfg).init(jax.random.PRNGKey(0), dense_cfg)
    rt = ServiceRuntime(dense_cfg, params,
                        ParallelPlan(service="t", category=LAT, bs=2),
                        kvcache_impl="paged", max_seq_len=64, block_size=8)
    rt.submit(GenerationRequest(rid=0,
                                tokens=np.arange(1, 6, dtype=np.int32),
                                max_new_tokens=2))
    rt.drain()
    traces = rt.decode_traces
    cost = rt.decode_cost_analysis()
    assert cost.get("bytes accessed", 0) > 0
    assert rt.decode_traces == traces      # throwaway lowering, no drift


# ---------------------------------------------------------------------------
# kernels: ref fallback's length-clipped gather stays bit-identical
# ---------------------------------------------------------------------------

def test_paged_decode_ref_masked_gather_bit_identical(rng):
    """ops.paged_decode_attention's ref fallback clips the block table to
    per-slot up-to-len rows (past-len entries read the one trash page).
    The clip must be invisible to the math: bit-identical to the oracle
    on the UNCLIPPED gather."""
    from repro.kernels import ops, ref
    from repro.kernels.decode_attention import paged_gather_ref
    B, Hq, Hkv, D, bs, nblk, P = 3, 4, 2, 16, 8, 4, 14
    q = jnp.asarray(rng.normal(size=(B, Hq, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.permutation(P - 1)[:B * nblk]
                     .reshape(B, nblk).astype(np.int32))
    lens = jnp.asarray(np.array([3, 17, 32], np.int32))
    want = ref.decode_attention_ref(q, paged_gather_ref(kp, bt),
                                    paged_gather_ref(vp, bt), lens)
    got = ops.paged_decode_attention(q, kp, vp, bt, lens, impl="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_chunk_ref_masked_gather_bit_identical(rng):
    from repro.kernels import ops, ref
    from repro.kernels.decode_attention import paged_gather_ref
    B, T, Hq, Hkv, D, bs, nblk, P = 2, 8, 4, 2, 16, 8, 4, 10
    q = jnp.asarray(rng.normal(size=(B, T, Hq, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.permutation(P - 1)[:B * nblk]
                     .reshape(B, nblk).astype(np.int32))
    start = jnp.asarray(np.array([4, 19], np.int32))
    cl = jnp.asarray(np.array([8, 6], np.int32))
    want = ref.chunk_attention_ref(q, paged_gather_ref(kp, bt),
                                   paged_gather_ref(vp, bt), start, cl)
    got = ops.paged_chunk_attention(q, kp, vp, bt, start, cl, impl="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_native_pallas_interpret_matches_ref():
    """The fused engine path under impl='pallas_interpret' (the scalar-
    prefetch block-table kernels) must produce the ref path's greedy
    tokens — the CI stand-in for the real-TPU bit-exactness gate."""
    cfg = _CFGS["dense"]
    params = _family_params("dense")
    rng = np.random.default_rng(3)
    reqs = _requests(cfg, rng, n_reqs=3)
    _, ref_toks = _serve(cfg, params, reqs, bs=2, kvcache_impl="paged",
                         impl="ref")
    _, pallas_toks = _serve(cfg, params, reqs, bs=2, kvcache_impl="paged",
                            impl="pallas_interpret")
    assert ref_toks == pallas_toks


# ---------------------------------------------------------------------------
# gating and validation
# ---------------------------------------------------------------------------

def test_paged_native_gating():
    """Pure-SSM families and ring (sliding-window) layouts keep the
    dense-view/state path; forcing paged_native there must fail loudly."""
    cfg = _CFGS["ssm"]
    params = _family_params("ssm")
    rt = ServiceRuntime(cfg, params,
                        ParallelPlan(service="t", category=LAT, bs=2),
                        kvcache_impl="paged", max_seq_len=48, block_size=8)
    assert not rt.paged_native
    with pytest.raises(ValueError):
        ServiceRuntime(cfg, params,
                       ParallelPlan(service="t", category=LAT, bs=2),
                       kvcache_impl="paged", max_seq_len=48, block_size=8,
                       paged_native=True)
    ring_cfg = toy_config(sliding_window=16)     # < 48-token slot budget
    ring_params = model_api(ring_cfg).init(jax.random.PRNGKey(0), ring_cfg)
    rt = ServiceRuntime(ring_cfg, ring_params,
                        ParallelPlan(service="t", category=LAT, bs=2),
                        kvcache_impl="paged", max_seq_len=48, block_size=8)
    assert not rt.paged_native and rt.ring_fallback


# ---------------------------------------------------------------------------
# batched COW (PR 4 follow-up satellite)
# ---------------------------------------------------------------------------

def test_cow_blocks_batches_one_dispatch(dense_cfg):
    """Several divergence COWs coalesce into ONE jitted scatter: contents
    copied faithfully, refcounts correct, exactly one dispatch counted."""
    from repro.models import transformer as T
    from repro.serving.arena import KVArena

    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    arena = KVArena(dense_cfg, T.init_cache, capacity=3, max_seq_len=32,
                    block_size=8)
    prompt = jnp.asarray(np.arange(1, 17, dtype=np.int32)[None])
    _, cache = T.prefill(params, dense_cfg, {"tokens": prompt},
                         cache_size=arena.slot_tokens)
    owner = arena.alloc(32)
    arena.write_prefill(owner, cache, prompt_len=16)
    shared = list(arena.block_tables()[owner][:2])
    s1 = arena.alloc(32, shared=shared)
    s2 = arena.alloc(32, shared=shared)
    before = arena.dense_view(arena.pages,
                              jnp.asarray(arena.block_tables()))
    copied = arena.cow_blocks([(s1, 0), (s1, 1), (s2, 0)])
    assert copied == 3
    assert arena.cow_calls == 1              # one dispatch for the wave
    after = arena.dense_view(arena.pages, jnp.asarray(arena.block_tables()))
    for b, a in zip(before, after):          # copies are faithful and the
        np.testing.assert_array_equal(       # owner's rows untouched
            np.asarray(b[:, [owner, s1, s2], :16]),
            np.asarray(a[:, [owner, s1, s2], :16]))
    # the three sharers now own private physical blocks
    bt = arena.block_tables()
    assert bt[s1][0] != bt[owner][0] and bt[s2][0] != bt[owner][0]
    assert bt[s1][0] != bt[s2][0]
    assert arena.block_ref(int(bt[owner][0])) == 1


def test_cow_blocks_exhaustion_leaves_state_consistent(dense_cfg):
    """When the pool cannot supply every destination, cow_blocks must
    raise BEFORE mutating anything: no pair may be left pointing at a
    claimed-but-never-copied block (destinations are claimed up front,
    bookkeeping mutates only after the claim succeeds)."""
    from repro.models import transformer as T
    from repro.serving.arena import KVArena

    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    arena = KVArena(dense_cfg, T.init_cache, capacity=2, max_seq_len=32,
                    block_size=8, pool_blocks=6)
    prompt = jnp.asarray(np.arange(1, 17, dtype=np.int32)[None])
    _, cache = T.prefill(params, dense_cfg, {"tokens": prompt},
                         cache_size=arena.slot_tokens)
    owner = arena.alloc(32)
    arena.write_prefill(owner, cache, prompt_len=16)
    shared = list(arena.block_tables()[owner][:2])
    sharer = arena.alloc(32, shared=shared)      # pool now exhausted
    bt_before = arena.block_tables().copy()
    refs_before = [arena.block_ref(int(b)) for b in shared]
    with pytest.raises(RuntimeError):
        arena.cow_blocks([(sharer, 0), (sharer, 1)])
    np.testing.assert_array_equal(arena.block_tables(), bt_before)
    assert [arena.block_ref(int(b)) for b in shared] == refs_before
    assert arena.cow_copies == 0 and arena.cow_calls == 0


def test_admission_wave_cows_coalesce(dense_cfg):
    """Engine satellite: a wave of admissions sharing one template's
    partial tail must flush its divergence COWs as one batched dispatch
    (arena.cow_calls grows by ~1 per wave, not per admission)."""
    from repro.core.categories import Sensitivity, TaskCategory
    from repro.models import transformer as T

    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    plan = ParallelPlan(service="t",
                        category=TaskCategory(Sensitivity.FREQUENCY, False),
                        bs=4)
    rt = ServiceRuntime(dense_cfg, params, plan, kvcache_impl="paged",
                        max_seq_len=96, block_size=8)
    rng = np.random.default_rng(2)
    template = rng.integers(1, 257, 20).astype(np.int32)  # 2 full + partial
    rt.submit(GenerationRequest(rid=0, tokens=template, max_new_tokens=2))
    rt.drain()                                # template indexed on eviction
    arena = rt.groups[0].arena
    calls0, copies0 = arena.cow_calls, arena.cow_copies
    for i in range(1, 4):                     # one wave of partial-tail hits
        rt.submit(GenerationRequest(
            rid=i, tokens=np.concatenate(
                [template, rng.integers(1, 257, 4).astype(np.int32)]),
            max_new_tokens=2))
    rt.drain()
    new_copies = arena.cow_copies - copies0
    assert new_copies >= 2                    # the wave really did COW
    assert arena.cow_calls - calls0 < new_copies  # ...in fewer dispatches


def test_chunk_write_bytes_not_counted_as_admission_copies(dense_cfg):
    """Satellite fix: _run_chunk's appends land in chunk_write_bytes, so a
    pure chunked-admission run reports ZERO admission-copy bytes."""
    from repro.models import transformer as T
    params = T.init(jax.random.PRNGKey(0), dense_cfg)
    rt = ServiceRuntime(dense_cfg, params,
                        ParallelPlan(service="t", category=LAT, bs=2),
                        kvcache_impl="paged", max_seq_len=64, block_size=8)
    rt.submit(GenerationRequest(rid=0,
                                tokens=np.arange(1, 40, dtype=np.int32),
                                max_new_tokens=2))
    rt.drain()
    assert rt.admission_copy_bytes == 0
    assert rt.chunk_write_bytes > 0


# ---------------------------------------------------------------------------
# launcher: pjit'd paged decode under a service mesh
# ---------------------------------------------------------------------------

def test_pjit_paged_decode_builder_matches_local_jit():
    """The launcher's paged_step_builder (pjit under a service mesh) must
    produce the same greedy tokens as the engine's local jit, still with
    exactly one decode compile."""
    from repro.launch import mesh as meshlib
    from repro.launch.steps import paged_decode_builder

    cfg = _CFGS["dense"]
    params = _family_params("dense")
    rng = np.random.default_rng(9)
    reqs = _requests(cfg, rng, n_reqs=3)
    mesh = meshlib.make_mesh((1, jax.device_count()), ("data", "model"))
    builder = paged_decode_builder(mesh)
    rt_m, mesh_toks = _serve(cfg, params, reqs, bs=2, kvcache_impl="paged",
                             paged_step_builder=builder)
    _, local_toks = _serve(cfg, params, reqs, bs=2, kvcache_impl="paged")
    assert mesh_toks == local_toks
    assert rt_m.decode_traces <= 1
