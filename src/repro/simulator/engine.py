"""Event-driven edge-cloud co-simulation (§5.2 methodology).

The simulator "fully executes the request scheduling process but bypasses
actual packet transmission and model computation": transmission latency is
priced from payload/bandwidth, computation latency from the shared roofline
cost model (repro.core.costmodel) — both identical to what the control
plane itself believes, so scheduler quality (not cost-model mismatch) is
what the experiments measure.

Execution model per (server, service): capacity c reqs/s from the placed
plans; latency requests flow through a virtual single-queue (finish = max
(now, vf) + 1/c + base latency); frequency streams reserve fps for their
duration (partial credit at stream end via ``frequency_credit``).  Only
request-level schedulers (EPARA) may split one stream across replica
groups/servers — the Fig. 1 effect.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import costmodel as cm
from repro.core.allocator import plan_goodput
from repro.core.categories import (GPUSpec, Outcome, Request, ServerSpec,
                                   ServiceSpec)
from repro.core.cluster import EdgeCloudControlPlane
from repro.core.goodput import (GoodputMeter, deadline_expired,
                                frequency_credit)
from repro.core.placement import EPSILON_SERVER

from .baselines import Route, Scheduler
from .workload import demand_matrix


@dataclasses.dataclass
class SimConfig:
    horizon_s: float = 120.0
    sync_interval_s: float = 1.0
    placement_interval_s: float = 60.0
    inter_server_bw_gbs: float = 1.25
    seed: int = 0
    # data-plane service discipline for latency tasks, mirroring the live
    # engine's three paths.  "paged" (the arena data plane) admits as
    # capacity frees with zero admission overhead: pure 1/c fluid flow.
    # "continuous" is the dense slot loop: the same fluid flow plus
    # ``admission_copy_s`` per admission (the kvcache.merge whole-batch
    # copy + retrace stall the arena removes; 0 by default so legacy
    # configs are unchanged).  "sync" models the pre-slot run-to-completion
    # engine: requests barrier until a full ``bs`` batch forms (or
    # ``sync_flush_s`` passes) and every member holds its slot for the
    # full batch latency.
    serving_mode: str = "paged"
    sync_flush_s: float = 0.05
    admission_copy_s: float = 0.0
    # chunked-prefill cost model (paged/continuous latency service): an
    # admission's prompt costs ``prompt_tokens * prefill_token_s`` of
    # serial prefill work.  Unchunked (prefill_chunk_tokens = 0) the WHOLE
    # prompt stalls the service's virtual queue in one piece — every live
    # request behind it waits (head-of-line blocking).  Chunked, the stall
    # imposed on the shared queue is capped at one chunk
    # (``min(prompt, chunk) * prefill_token_s``): the remaining chunks
    # interleave with decode steps, so only the arriving request itself
    # pays for them.  Placement sees the effect through goodput/queue
    # delay; ``SimResult.max_prefill_stall_s`` reports the worst stall.
    prefill_chunk_tokens: int = 0
    prefill_token_s: float = 0.0
    # radix prefix cache (live engine's shared-prefix KV reuse): the
    # expected fraction of an admission's prompt tokens served from cached
    # blocks instead of prefill compute.  Applied only to services whose
    # plan enables the cache (``ParallelPlan.prefix_cache != 0``), so SSSP
    # placement prices repeated-prefix (frequency) workloads at their
    # post-reuse prefill cost — reuse-aware capacity feeds placement
    # quality.  ``SimResult.cached_prefill_s`` reports the total prefill
    # seconds the cache removed.
    prefix_hit_rate: float = 0.0
    # per-service hit rates derived from the workload's actual template-
    # repeat structure (``workload.derive_prefix_hit_rates``); a service
    # present here overrides the scalar ``prefix_hit_rate``, absent
    # services fall back to it.  None = scalar-only (legacy configs).
    prefix_hit_rates: Optional[Mapping[str, float]] = None
    # request-admission policy for latency tasks on the paged/continuous
    # data plane, mirroring the live engine's ``ParallelPlan.admission``
    # knob.  "fifo" (legacy): every arrival joins the fluid queue, doomed
    # requests burn capacity and finish late.  "sdf" (Strictest-Deadline-
    # First): arrivals whose own service time alone exceeds the remaining
    # deadline budget are shed with a DEADLINE_MISSED verdict (no capacity
    # spent), and arrivals that would miss only because of queue wait
    # preempt — jump the virtual queue at ``preempt_overhead_s`` extra
    # latency (the park/resume block-table cost) while the displaced work
    # still occupies the server, so SSSP placement prices preemption.
    admission_policy: str = "fifo"
    preempt_overhead_s: float = 0.0005
    # speculative decoding (live engine's draft/verify rounds): a round
    # proposes k draft tokens and commits 1 + accept_rate*k of them per
    # fused target launch, at ``spec_draft_cost`` target-step-fractions
    # per draft step.  The decode term of a speculating latency service is
    # scaled by (1 + draft_cost*(k+1)) / (1 + accept_rate*k) — the
    # acceptance-rate-discounted serial-launch count.  Both fields default
    # 0 => factor 1 (legacy configs unchanged); k comes from the plan's
    # ``resolved_speculate`` knob, so only services whose category/plan
    # actually speculates are discounted.
    spec_accept_rate: float = 0.0
    spec_draft_cost: float = 0.0
    # deterministic failure processes (core/faults.py FaultSpec): the
    # same replayable schedule the live ClusterSupervisor consumes, run
    # through the event heap.  A crash zeroes the server's capacity and
    # flags it in the control plane (the ring heals around it; peers
    # stop scoring its frozen digest past the staleness bound); work
    # admitted before the crash but unfinished at it is LOST and
    # resubmits through the handler after ``failover_retry_s`` — or
    # draws a FAILED verdict when its deadline already passed.  A
    # restart lifts the flag immediately (ring rejoin + re-publish) but
    # capacity only returns after ``restart_reload_s`` (weight reload).
    # ``drop_offload`` swallows handoffs TO the named server; the origin
    # retries them after the same delay.  None = fault-free (legacy).
    fault_spec: Optional[object] = None
    restart_reload_s: float = 2.0
    failover_retry_s: float = 0.5


@dataclasses.dataclass
class SimResult:
    scheduler: str
    goodput: float              # satisfied credits / sec
    offered: float
    fulfillment: float
    violations: int
    offload_counts: List[int]
    handled: int

    first_hops: int = 1
    max_prefill_stall_s: float = 0.0   # worst single-admission prefill
    #                                    stall imposed on live requests
    cached_prefill_s: float = 0.0      # prefill seconds removed by the
    #                                    prefix cache (hit-rate model)
    verdicts: Dict[str, int] = dataclasses.field(default_factory=dict)
    #                                  # admission-verdict counts (Outcome
    #                                    values) under the "sdf" policy
    preemptions: int = 0               # queue-jump admissions (modeled
    #                                    block-table-parking preemptions)
    spec_discounted: int = 0           # requests priced at the
    #                                    speculative-decoding discount
    crashes: int = 0                   # injected server crashes
    failover_resubmits: int = 0        # requests whose in-flight compute
    #                                    a crash (or dropped handoff)
    #                                    destroyed, rerouted to survivors
    dropped_offloads: int = 0          # handoffs the adversary swallowed

    @property
    def mean_offloads(self) -> float:
        """Offload hops per arriving request (the paper's Fig. 17e metric:
        <1 when sync is fresh; grows with staleness)."""
        return len(self.offload_counts) / max(1, self.first_hops)


class _ServerState:
    __slots__ = ("capacity", "vf", "stream_load", "forming", "forming_gen")

    def __init__(self):
        self.capacity: Dict[str, float] = {}
        self.vf: Dict[str, float] = {}          # virtual finish per service
        self.stream_load: Dict[str, float] = {}  # reserved fps
        self.forming: Dict[str, list] = {}       # sync mode: batch barrier
        self.forming_gen: Dict[str, int] = {}    # guards stale flush events


class Simulation:
    def __init__(self, servers: Sequence[ServerSpec],
                 services: Mapping[str, ServiceSpec],
                 scheduler: Scheduler,
                 events: Sequence[Tuple[float, int, Request]],
                 cfg: SimConfig = SimConfig()):
        self.servers = list(servers)
        self.services = dict(services)
        self.scheduler = scheduler
        self.cfg = cfg
        if cfg.serving_mode not in ("paged", "continuous", "sync"):
            raise ValueError(
                f"serving_mode must be paged|continuous|sync, got "
                f"{cfg.serving_mode!r}")
        if not 0.0 <= cfg.prefix_hit_rate < 1.0:
            raise ValueError(
                f"prefix_hit_rate must be in [0, 1), got "
                f"{cfg.prefix_hit_rate!r}")
        for name, r in (cfg.prefix_hit_rates or {}).items():
            if not 0.0 <= r < 1.0:
                raise ValueError(
                    f"prefix_hit_rates[{name!r}] must be in [0, 1), got "
                    f"{r!r}")
        if cfg.admission_policy not in ("fifo", "sdf"):
            raise ValueError(
                f"admission_policy must be fifo|sdf, got "
                f"{cfg.admission_policy!r}")
        self.meter = GoodputMeter()
        self.server_ids = [s.sid for s in self.servers]
        self.state: Dict[int, _ServerState] = {
            s.sid: _ServerState() for s in self.servers}
        self.control_plane = EdgeCloudControlPlane(
            self.servers, self.services,
            sync_interval_s=cfg.sync_interval_s,
            placement_interval_s=cfg.placement_interval_s, seed=cfg.seed)
        # EPARA's control plane must use the scheduler's plans
        self.control_plane.plans = dict(scheduler.plans)
        self._events = sorted(events, key=lambda e: e[0])
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self._offload_counts: List[int] = []
        self._handled = 0
        self._first_hops = 0
        self._max_prefill_stall = 0.0
        self._cached_prefill_s = 0.0
        self._verdicts: Dict[str, int] = {}
        self._preemptions = 0
        self._spec_discounted = 0
        self.placements: List[Tuple[str, int]] = []
        # failure-process state: crash times per sid (a done event whose
        # host crashed inside its (admit, finish) window lost its compute)
        self._down: set = set()
        self._crash_times: Dict[int, List[float]] = {}
        self._saved_capacity: Dict[int, Dict[str, float]] = {}
        self._drop_budget: Dict[int, int] = {}
        self._crashes = 0
        self._failover_resubmits = 0
        self._dropped_offloads = 0

    def _note_verdict(self, outcome: Outcome) -> None:
        key = outcome.value
        self._verdicts[key] = self._verdicts.get(key, 0) + 1

    # ------------------------------------------------------------------
    # context interface consumed by baseline schedulers
    # ------------------------------------------------------------------
    def is_placed(self, sid: int, service: str) -> bool:
        return self.state[sid].capacity.get(service, 0.0) > 0

    def has_capacity(self, sid: int, service: str, now: float) -> bool:
        st = self.state[sid]
        cap = st.capacity.get(service, 0.0) - st.stream_load.get(service, 0.0)
        if cap <= 0:
            return False
        svc = self.services[service]
        return self.queue_time(sid, service, now) <= svc.slo_latency_s

    def queue_time(self, sid: int, service: str, now: float) -> float:
        st = self.state[sid]
        return max(0.0, st.vf.get(service, 0.0) - now)

    # ------------------------------------------------------------------
    def _apply_placement(self, placements, now: float) -> None:
        self.placements = list(placements)
        self.control_plane.placements = list(placements)
        gpu = self.servers[0].gpu
        for st in self.state.values():
            st.capacity.clear()
        pooled: Dict[str, float] = {}
        for svc_name, sid in placements:
            svc = self.services[svc_name]
            plan = self.scheduler.plans[svc_name]
            g = plan_goodput(svc, gpu, plan,
                             cross_server=(sid == EPSILON_SERVER))
            if sid == EPSILON_SERVER:
                pooled[svc_name] = pooled.get(svc_name, 0.0) + g
            else:
                cap = self.state[sid].capacity
                cap[svc_name] = cap.get(svc_name, 0.0) + g
        # ε capacity: spread across the least-loaded servers
        for svc_name, g in pooled.items():
            share = g / len(self.servers)
            for st in self.state.values():
                st.capacity[svc_name] = st.capacity.get(svc_name, 0.0) + share

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        # initial placement from the full offered demand (offline mode §3.3)
        demand = demand_matrix(self._events, self.services, cfg.horizon_s)
        placements = self.scheduler.place(
            self.control_plane.build_problem(demand))
        self._apply_placement(placements, 0.0)

        push = lambda t, kind, payload: heapq.heappush(
            self._heap, (t, next(self._seq), kind, payload))
        for t, sid, req in self._events:
            self.meter.offered(req)
            push(t, "arrival", (sid, req))
        t = cfg.sync_interval_s
        while t < cfg.horizon_s:
            push(t, "sync", ())
            t += cfg.sync_interval_s
        if cfg.fault_spec is not None:
            for ev in cfg.fault_spec.events:
                push(ev.at_s, "fault", (ev,))

        while self._heap:
            now, _, kind, payload = heapq.heappop(self._heap)
            if kind == "sync":
                self.control_plane.publish_all(now)
                self.control_plane.sync_step(now)
            elif kind == "arrival":
                sid, req = payload
                self._handle(req, sid, now, push)
            elif kind == "done":
                req, finish, sid, admit_t = payload
                if self._crashed_during(sid, admit_t, finish):
                    # the host died under it: the virtual queue's compute
                    # never happened — reroute to a survivor (or FAILED)
                    self._resubmit(req, sid, now, push)
                else:
                    self.meter.complete_latency(req, finish)
            elif kind == "fault":
                self._apply_fault(payload[0], now, push)
            elif kind == "reload":
                sid = payload[0]
                saved = self._saved_capacity.pop(sid, None)
                if saved is not None and sid not in self._down:
                    self.state[sid].capacity = saved
            elif kind == "fault_restore":
                sid, factor = payload
                for table in (self.state[sid].capacity,
                              self._saved_capacity.get(sid)):
                    if table:
                        for k in table:
                            table[k] *= factor
            elif kind == "batch_flush":
                sid, service, gen = payload
                st = self.state[sid]
                if (st.forming_gen.get(service, 0) == gen
                        and st.forming.get(service)):
                    self._dispatch_batch(sid, service, now, push)
            elif kind == "stream_end":
                req, achieved, sid = payload
                svc = self.services[req.service]
                st = self.state[sid]
                st.stream_load[req.service] = max(
                    0.0, st.stream_load.get(req.service, 0.0) - achieved)
                start = now - req.duration_s
                for t_c in self._crash_times.get(sid, ()):
                    if start <= t_c <= now:
                        # partial credit: frames delivered before the host
                        # crashed; the rest of the stream died with it
                        achieved *= max(0.0, (t_c - start)
                                        / max(1e-9, req.duration_s))
                        break
                self.meter.complete_frequency(req, now, achieved,
                                              svc.slo_fps)
        horizon = cfg.horizon_s
        return SimResult(
            scheduler=self.scheduler.name,
            goodput=self.meter.total_credit / horizon,
            offered=self.meter.total_offered / horizon,
            fulfillment=self.meter.fulfillment_ratio,
            violations=self.meter.violations,
            offload_counts=self._offload_counts,
            handled=self._handled, first_hops=max(1, self._first_hops),
            max_prefill_stall_s=self._max_prefill_stall,
            cached_prefill_s=self._cached_prefill_s,
            verdicts=dict(self._verdicts),
            preemptions=self._preemptions,
            spec_discounted=self._spec_discounted,
            crashes=self._crashes,
            failover_resubmits=self._failover_resubmits,
            dropped_offloads=self._dropped_offloads)

    # ------------------------------------------------------------------
    # failure processes (core/faults.py schedules on the event heap)
    # ------------------------------------------------------------------
    def _crashed_during(self, sid: int, start: float, end: float) -> bool:
        return any(start <= t <= end
                   for t in self._crash_times.get(sid, ()))

    def _resubmit(self, req: Request, dead_sid: int, now: float,
                  push) -> None:
        """Recover a request whose compute a fault destroyed: reroute it
        through the handler from a surviving server after the retry
        delay — or issue the explicit FAILED verdict when its deadline
        (or the cluster) is already gone."""
        retry_at = now + self.cfg.failover_retry_s
        alive = [s for s in self.server_ids if s not in self._down]
        if not alive or deadline_expired(req.deadline_s, retry_at):
            self._note_verdict(Outcome.FAILED)
            self.meter.drop(req, now)
            return
        from repro.core.handler import RequestHandler
        fwd = RequestHandler.apply_offload(req, dead_sid)
        self._failover_resubmits += 1
        push(retry_at, "arrival", (alive[0], fwd))

    def _apply_fault(self, ev, now: float, push) -> None:
        st = self.state.get(ev.sid)
        if st is None:
            return
        if ev.kind == "crash":
            if ev.sid in self._down:
                return
            self._down.add(ev.sid)
            self._crashes += 1
            self._crash_times.setdefault(ev.sid, []).append(now)
            self.control_plane.fail_server(ev.sid, now)
            self._saved_capacity[ev.sid] = dict(st.capacity)
            st.capacity = {}
            st.vf.clear()
            st.stream_load.clear()
            # sync-mode batch barriers on the corpse: members resubmit
            for service, forming in list(st.forming.items()):
                st.forming_gen[service] = \
                    st.forming_gen.get(service, 0) + 1
                for req in forming:
                    self._resubmit(req, ev.sid, now, push)
                st.forming[service] = []
        elif ev.kind == "restart":
            if ev.sid not in self._down:
                return
            self._down.discard(ev.sid)
            # ring rejoin is immediate; serving capacity only returns
            # after the weight reload
            self.control_plane.repair_server(ev.sid, now)
            push(now + self.cfg.restart_reload_s, "reload", (ev.sid,))
        elif ev.kind == "straggle":
            factor = max(1.0, ev.factor)
            for table in (st.capacity, self._saved_capacity.get(ev.sid)):
                if table:
                    for k in table:
                        table[k] /= factor
            push(now + ev.duration_s, "fault_restore", (ev.sid, factor))
        elif ev.kind == "corrupt":
            self.control_plane.sync.corrupt(ev.sid, factor=ev.factor)
        elif ev.kind == "drop_offload":
            self._drop_budget[ev.sid] = \
                self._drop_budget.get(ev.sid, 0) + ev.count

    # ------------------------------------------------------------------
    def _handle(self, req: Request, sid: int, now: float, push) -> None:
        self._handled += 1
        if req.offload_count == 0:
            self._first_hops += 1
        svc = self.services[req.service]
        sched_lat = self.scheduler.scheduling_latency(len(self.servers))
        now = now + sched_lat
        route = self.scheduler.route(req, sid, now, self)
        if route.outcome == Outcome.TIMEOUT or deadline_expired(
                req.deadline_s, now):
            self._note_verdict(Outcome.TIMEOUT)
            self.meter.drop(req, now)
            return
        if route.outcome in (Outcome.OFFLOAD,):
            dest = route.destination
            budget = self._drop_budget.get(dest, 0)
            if budget > 0:
                # the adversary swallows this handoff in flight; the
                # origin notices the missing ack and retries its routing
                self._drop_budget[dest] = budget - 1
                self._dropped_offloads += 1
                self._failover_resubmits += 1
                push(now + self.cfg.failover_retry_s, "arrival",
                     (sid, req))
                return
            hop = cm.transfer_time(svc.request_bytes,
                                   self.cfg.inter_server_bw_gbs)
            from repro.core.handler import RequestHandler
            fwd = RequestHandler.apply_offload(req, sid)
            self._offload_counts.append(fwd.offload_count)
            push(now + hop, "arrival", (dest, fwd))
            return
        if route.outcome in (Outcome.OFFLOAD_EXCEEDED, Outcome.INSUFFICIENT):
            self.meter.drop(req, now)
            return
        # local-ish execution
        self._execute_local(req, sid, now, push)

    def _execute_local(self, req: Request, sid: int, now: float,
                       push) -> None:
        svc = self.services[req.service]
        plan = self.scheduler.plans[req.service]
        st = self.state[sid]
        cap = st.capacity.get(req.service, 0.0)
        if cap <= 0:
            self.meter.drop(req, now)
            return
        if svc.is_frequency and req.duration_s > 0:
            demand_fps = req.frames / req.duration_s
            idle = max(0.0, cap - st.stream_load.get(req.service, 0.0))
            achievable = min(demand_fps, idle,
                             self.scheduler.stream_fps_cap(svc))
            if self.scheduler.request_level and achievable < demand_fps:
                # EPARA request-level DP: split surplus frames across peers
                achievable += self._peer_stream_share(
                    req, sid, demand_fps - achievable)
                achievable = min(achievable, demand_fps)
            st.stream_load[req.service] = \
                st.stream_load.get(req.service, 0.0) + achievable
            push(now + req.duration_s, "stream_end",
                 (req, achievable, sid))
        elif self.cfg.serving_mode == "sync":
            # run-to-completion barrier: the request waits for a full batch
            # (or the flush timer), then holds its slot for the whole batch
            forming = st.forming.setdefault(req.service, [])
            forming.append(req)
            gen = st.forming_gen.setdefault(req.service, 0)
            if len(forming) >= plan.bs:
                self._dispatch_batch(sid, req.service, now, push)
            elif len(forming) == 1:
                push(now + self.cfg.sync_flush_s, "batch_flush",
                     (sid, req.service, gen))
        else:
            # paged/continuous admission: the slot loop admits as capacity
            # frees, so latency service behaves as a 1/c fluid flow per
            # request.  The dense ("continuous") impl additionally pays
            # ``admission_copy_s`` per admission — the whole-live-batch
            # kvcache.merge copy and decode retrace the paged arena
            # eliminates (its admissions only scatter the new pages).
            eff_cap = max(1e-6, cap - st.stream_load.get(req.service, 0.0))
            vf0 = max(now, st.vf.get(req.service, now))
            own = 1.0 / eff_cap
            if self.cfg.serving_mode == "continuous":
                own += self.cfg.admission_copy_s
            # chunked-prefill model: the prompt's prefill is serial work.
            # Unchunked it lands on the SHARED virtual queue in one piece
            # (head-of-line blocking: every later finish waits); chunked,
            # only one chunk's worth stalls the queue — the rest
            # interleaves with decode, so only this request's own finish
            # pays for it.
            prefill_s = req.prompt_tokens * self.cfg.prefill_token_s
            # the discount mirrors the live gate exactly: paged data plane
            # + chunked prefill + token-pure family + plan knob on —
            # configurations where the real engine cannot reuse must not
            # be priced as if they could
            hit_rate = self.cfg.prefix_hit_rate
            if self.cfg.prefix_hit_rates is not None:
                hit_rate = self.cfg.prefix_hit_rates.get(req.service,
                                                         hit_rate)
            if (hit_rate > 0 and prefill_s > 0
                    and self.cfg.serving_mode == "paged"
                    and self.cfg.prefill_chunk_tokens > 0
                    and svc.prefix_cacheable
                    and getattr(plan, "prefix_cache", 0) != 0):
                # hit-rate-aware prefill: cached prefix tokens skip
                # compute, so the shared queue (and with it goodput /
                # placement quality) sees the post-reuse cost
                saved = prefill_s * hit_rate
                prefill_s -= saved
                self._cached_prefill_s += saved
            stall = prefill_s
            if prefill_s > 0:
                chunk = self.cfg.prefill_chunk_tokens
                if chunk > 0:
                    stall = (min(req.prompt_tokens, chunk)
                             * self.cfg.prefill_token_s)
                self._max_prefill_stall = max(self._max_prefill_stall,
                                              stall)
            own += stall
            base = cm.effective_latency(svc, self.servers[0].gpu,
                                        batch=plan.bs, mp=plan.mp,
                                        mt=plan.mt, mf=plan.mf) / plan.bs
            # speculative-decoding discount: mirror the live gate (paged
            # plane, token-pure family, plan knob speculating) and scale
            # the decode term by the acceptance-rate-discounted launch
            # count — k accepted drafts ride each verify, bought with
            # (k+1) draft steps at spec_draft_cost each
            k_spec = (plan.resolved_speculate(True)
                      if hasattr(plan, "resolved_speculate") else 0)
            if (k_spec > 0 and self.cfg.serving_mode == "paged"
                    and svc.prefix_cacheable
                    and (self.cfg.spec_accept_rate > 0
                         or self.cfg.spec_draft_cost > 0)):
                base *= ((1.0 + self.cfg.spec_draft_cost * (k_spec + 1))
                         / (1.0 + self.cfg.spec_accept_rate * k_spec))
                self._spec_discounted += 1
            tail = prefill_s - stall   # non-stalling chunks: own cost only
            if self.cfg.admission_policy == "sdf" and req.deadline_s:
                # slack-ordered admission (live engine's AdmissionController
                # mirrored in fluid-flow terms): slack = deadline budget
                # minus this request's OWN unavoidable service time
                slack = req.deadline_s - now - (own + base + tail)
                if slack < 0:
                    # cannot finish even served immediately — shed before
                    # any capacity is spent (FIFO would serve it dead)
                    self._note_verdict(Outcome.DEADLINE_MISSED)
                    self.meter.drop(req, now)
                    return
                if vf0 - now > slack:
                    # queue wait alone would burn the slack: preempt by
                    # block-table parking — jump the virtual queue at the
                    # park/resume overhead, while the displaced decode
                    # work still occupies the server (vf advances by the
                    # full own-service time, conserving capacity)
                    self._preemptions += 1
                    self._note_verdict(Outcome.ADMIT)
                    st.vf[req.service] = vf0 + own
                    finish = (now + own + base + tail
                              + self.cfg.preempt_overhead_s)
                    push(finish, "done", (req, finish, sid, now))
                    return
                self._note_verdict(Outcome.ADMIT)
            vf = vf0 + own
            st.vf[req.service] = vf
            finish = vf + base + tail
            push(finish, "done", (req, finish, sid, now))

    def _dispatch_batch(self, sid: int, service: str, now: float,
                        push) -> None:
        """Sync mode: run one composed batch to completion; every member
        finishes together at the batch-wide latency (the barrier cost the
        continuous engine removes)."""
        st = self.state[sid]
        batch = st.forming.pop(service, [])
        st.forming_gen[service] = st.forming_gen.get(service, 0) + 1
        if not batch:
            return
        svc = self.services[service]
        plan = self.scheduler.plans[service]
        # a flush-timer partial batch only pays for its own size; the sync
        # cost is the barrier wait + whole-batch hold, not padded compute
        batch_lat = cm.effective_latency(svc, self.servers[0].gpu,
                                         batch=len(batch), mp=plan.mp,
                                         mt=plan.mt, mf=plan.mf)
        vf = max(now, st.vf.get(service, now)) + batch_lat
        st.vf[service] = vf
        for req in batch:
            push(vf, "done", (req, vf, sid, now))

    def _peer_stream_share(self, req: Request, sid: int,
                           needed_fps: float) -> float:
        """Round-robin the stream's surplus frames across peers with idle
        capacity (request-level DP across servers)."""
        got = 0.0
        for s in self.server_ids:
            if s == sid or needed_fps - got <= 1e-9:
                continue
            st = self.state[s]
            idle = max(0.0, st.capacity.get(req.service, 0.0)
                       - st.stream_load.get(req.service, 0.0))
            take = min(idle, needed_fps - got) * 0.9  # offload discount
            if take > 0:
                st.stream_load[req.service] = \
                    st.stream_load.get(req.service, 0.0) + take
                got += take
                # release happens with the stream (approximate: schedule on
                # the home server's stream_end; peers release via decay)
                self._schedule_peer_release(req, s, take)
        return got

    def _schedule_peer_release(self, req: Request, sid: int,
                               fps: float) -> None:
        heapq.heappush(self._heap, (
            req.arrival_s + req.duration_s, next(self._seq), "stream_end",
            (dataclasses.replace(req, frames=0), fps, sid)))


def run_comparison(servers, services, events, scheduler_names,
                   cfg: SimConfig = SimConfig(), *, seed: int = 0
                   ) -> Dict[str, SimResult]:
    from .baselines import make_scheduler
    gpu = servers[0].gpu
    out = {}
    for name in scheduler_names:
        sched = make_scheduler(name, services, gpu, seed=seed)
        sim = Simulation(servers, services, sched, events, cfg)
        out[name] = sim.run()
    return out
