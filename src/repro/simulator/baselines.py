"""Scheduler strategies: EPARA + the paper's comparison systems.

Each scheduler fixes (a) the operator policy (which of BS/MT/MP/MF/DP it
may use — Table 3's "Allocation Level"), (b) the placement policy, (c) the
routing policy, and (d) its per-decision scheduling latency.  The event
engine is strategy-agnostic.

  InterEdge   [4]  — decentralized, universal tasks: no request-level ops,
                     round-robin forwarding, MP/BS/MT aligned with EPARA.
  AlpaServe   [43] — datacenter: MP+BS centralized with perfect state; no
                     multi-server offload chains; refuses cross-server MP.
  Galaxy      [80] — centralized edge devices MP; no batching, no MT.
  SERV-P      [19] — centralized NP-hard placement+handling; scheduling
                     latency grows superlinearly with servers (Fig. 3e).
  USHER       [65] — interference-aware MP+BS+MT, centralized, no
                     request-level.
  DeTransformer [73] — communication-efficient cross-server MP, no MT/MF/DP.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import costmodel as cm
from repro.core.allocator import ParallelPlan, allocate, plan_goodput
from repro.core.categories import (GPUSpec, Request, ServerSpec, ServiceSpec,
                                   TaskCategory)
from repro.core.goodput import deadline_expired
from repro.core.handler import Outcome
from repro.core.placement import (EPSILON_SERVER, PlacementProblem, evaluate,
                                  sssp)


@dataclasses.dataclass(frozen=True)
class Route:
    outcome: Outcome
    destination: Optional[int] = None


class Scheduler:
    """Base class; subclasses override policy knobs."""
    name = "base"
    request_level = False          # DP + MF available?
    centralized = False
    allows_cross_server_mp = True
    allows_offload = True

    def __init__(self, services: Mapping[str, ServiceSpec],
                 gpu: GPUSpec, *, seed: int = 0):
        self.services = dict(services)
        self.gpu = gpu
        self.rng = random.Random(seed)
        self.plans = {n: self.plan_for(s) for n, s in self.services.items()}

    # -- operator policy ---------------------------------------------------
    def plan_for(self, svc: ServiceSpec) -> ParallelPlan:
        plan = allocate(svc, self.gpu)
        if not self.request_level:
            plan = dataclasses.replace(plan, dp=1, mf=1)
        return plan

    # -- placement policy -----------------------------------------------------
    def place(self, problem: PlacementProblem) -> List[Tuple[str, int]]:
        problem = dataclasses.replace(problem, plans=self.plans)
        return sssp(problem,
                    include_epsilon=self.allows_cross_server_mp)

    # -- routing policy ---------------------------------------------------------
    def scheduling_latency(self, num_servers: int) -> float:
        return 0.0005  # decentralized constant

    def stream_fps_cap(self, svc: ServiceSpec) -> float:
        """Max fps ONE stream can reach.  Without request-level DP a stream
        is unsplittable: capped at a single replica group's throughput."""
        plan = self.plans[svc.name]
        per_group = cm.throughput(svc, self.gpu, batch=plan.bs, mp=plan.mp,
                                  mt=plan.mt)
        if self.request_level:
            return per_group * max(1, plan.dp) * max(1, plan.mt)
        return per_group

    def route(self, req: Request, sid: int, now: float, ctx) -> Route:
        raise NotImplementedError


class EparaScheduler(Scheduler):
    name = "EPARA"
    request_level = True

    def route(self, req, sid, now, ctx) -> Route:
        decision = ctx.control_plane.handle(req, now, at_server=sid)
        return Route(decision.outcome, decision.destination)


class InterEdgeScheduler(Scheduler):
    name = "InterEdge"
    request_level = False

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self._rr = itertools.count()

    def place(self, problem):
        # spread every service round-robin until resources run out
        theta: List[Tuple[str, int]] = []
        from repro.core.placement import feasible
        problem = dataclasses.replace(problem, plans=self.plans)
        for svc in problem.services:
            for server in problem.servers:
                cand = (svc, server.sid)
                if feasible(problem, theta, cand):
                    theta.append(cand)
        return theta

    def route(self, req, sid, now, ctx) -> Route:
        if deadline_expired(req.deadline_s, now):
            return Route(Outcome.TIMEOUT)
        if ctx.has_capacity(sid, req.service, now):
            return Route(Outcome.LOCAL)
        if req.offload_count >= 5:
            return Route(Outcome.OFFLOAD_EXCEEDED)
        # state-blind round-robin forwarding
        peers = [s for s in ctx.server_ids if s != sid
                 and not req.on_path(s)]
        if not peers:
            return Route(Outcome.INSUFFICIENT)
        dest = peers[next(self._rr) % len(peers)]
        return Route(Outcome.OFFLOAD, dest)


class AlpaServeScheduler(Scheduler):
    name = "AlpaServe"
    request_level = False
    centralized = True
    allows_cross_server_mp = False   # refuses multi-server parallelism
    allows_offload = False

    def route(self, req, sid, now, ctx) -> Route:
        if deadline_expired(req.deadline_s, now):
            return Route(Outcome.TIMEOUT)
        # centralized dispatch with PERFECT state: least-loaded host
        best, best_load = None, float("inf")
        for s in ctx.server_ids:
            if not ctx.is_placed(s, req.service):
                continue
            load = ctx.queue_time(s, req.service, now)
            if load < best_load:
                best, best_load = s, load
        if best is None:
            return Route(Outcome.INSUFFICIENT)
        if best == sid:
            return Route(Outcome.LOCAL)
        return Route(Outcome.OFFLOAD, best)


class GalaxyScheduler(Scheduler):
    name = "Galaxy"
    request_level = False
    centralized = True

    def plan_for(self, svc):
        plan = allocate(svc, self.gpu)
        # no batching, no multi-task ([80] lacks both)
        return dataclasses.replace(plan, bs=1, mt=1, dp=1, mf=1)

    def route(self, req, sid, now, ctx) -> Route:
        if deadline_expired(req.deadline_s, now):
            return Route(Outcome.TIMEOUT)
        for s in ctx.server_ids:
            if ctx.is_placed(s, req.service) and \
                    ctx.has_capacity(s, req.service, now):
                return Route(Outcome.LOCAL if s == sid
                             else Outcome.OFFLOAD, None if s == sid else s)
        return Route(Outcome.INSUFFICIENT)


class ServPScheduler(Scheduler):
    name = "SERV-P"
    request_level = False
    centralized = True

    def plan_for(self, svc):
        plan = allocate(svc, self.gpu)
        # universal-task system: no AI-aware batching / MT
        return dataclasses.replace(plan, bs=1, mt=1, dp=1, mf=1)

    def scheduling_latency(self, num_servers: int) -> float:
        """Fig. 3e: ~100 ms at 10 servers, >750 ms at 30+ (groups of 10
        used in §5.2 to stay feasible)."""
        n = min(num_servers, 10)   # grouped scheduling
        return 1.0e-3 * n ** 2

    def route(self, req, sid, now, ctx) -> Route:
        if deadline_expired(req.deadline_s, now):
            return Route(Outcome.TIMEOUT)
        group = [s for s in ctx.server_ids if s // 10 == sid // 10]
        best, best_load = None, float("inf")
        for s in group:
            if ctx.is_placed(s, req.service):
                load = ctx.queue_time(s, req.service, now)
                if load < best_load:
                    best, best_load = s, load
        if best is None:
            return Route(Outcome.INSUFFICIENT)
        return Route(Outcome.LOCAL if best == sid else Outcome.OFFLOAD,
                     None if best == sid else best)


class UsherScheduler(Scheduler):
    name = "USHER"
    request_level = False
    centralized = True

    def route(self, req, sid, now, ctx) -> Route:
        if deadline_expired(req.deadline_s, now):
            return Route(Outcome.TIMEOUT)
        best, best_load = None, float("inf")
        for s in ctx.server_ids:
            if ctx.is_placed(s, req.service):
                load = ctx.queue_time(s, req.service, now)
                if load < best_load:
                    best, best_load = s, load
        if best is None:
            return Route(Outcome.INSUFFICIENT)
        return Route(Outcome.LOCAL if best == sid else Outcome.OFFLOAD,
                     None if best == sid else best)


class DeTransformerScheduler(GalaxyScheduler):
    name = "DeTransformer"

    def plan_for(self, svc):
        plan = allocate(svc, self.gpu)
        # block-parallel design keeps BS but no MT / request-level
        return dataclasses.replace(plan, mt=1, dp=1, mf=1)


SCHEDULERS = {
    "EPARA": EparaScheduler,
    "InterEdge": InterEdgeScheduler,
    "AlpaServe": AlpaServeScheduler,
    "Galaxy": GalaxyScheduler,
    "SERV-P": ServPScheduler,
    "USHER": UsherScheduler,
    "DeTransformer": DeTransformerScheduler,
}


def make_scheduler(name: str, services, gpu, *, seed: int = 0) -> Scheduler:
    return SCHEDULERS[name](services, gpu, seed=seed)
