"""Workloads for testbed-scale and large-scale simulations.

``table1_services()`` mirrors the paper's Table 1 model mix (vision
classify/detect/segment + text classify/translate/generate, in both
latency- and frequency-sensitive flavours), with FLOPs/weights taken from
the public model sizes.  Arrival processes follow the Azure Functions
2021 trace shape the paper samples: heavy-tailed per-function rates with
bursts (we synthesize matching statistics — Gamma inter-arrivals with
CV^2 ≈ 4 — since the trace itself isn't shipped offline).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.categories import Request, Sensitivity, ServiceSpec

GB = 1e9


def _svc(name, gflops, params_m, *, freq=False, fps=0.0, lat=0.5,
         vram_extra=1.5, arch=None, stateful=False):
    weights = params_m * 1e6 * 2.0        # bf16
    return ServiceSpec(
        name=name, flops_per_request=gflops * 1e9,
        weights_bytes=weights, vram_bytes=weights * vram_extra + 0.5 * GB,
        sensitivity=Sensitivity.FREQUENCY if freq else Sensitivity.LATENCY,
        slo_latency_s=lat, slo_fps=fps, arch=arch, stateful=stateful)


def table1_services(*, include_heavy: bool = True) -> Dict[str, ServiceSpec]:
    """The paper's Table 1 mix.  LLM per-request FLOPs ≈ 2 * N_active *
    generated tokens (256-token responses; prefill folded in)."""
    out: Dict[str, ServiceSpec] = {}
    # --- vision, frequency (video) --------------------------------------
    out["mobilenetv2-vid"] = _svc("mobilenetv2-vid", 0.6, 3.5,
                                  freq=True, fps=60, lat=0.1)
    out["resnet50-vid"] = _svc("resnet50-vid", 8.2, 25.6,
                               freq=True, fps=60, lat=0.1)
    out["yolov10-vid"] = _svc("yolov10-vid", 17.0, 29.5,
                              freq=True, fps=60, lat=0.1)
    out["unet-vid"] = _svc("unet-vid", 120.0, 31.0, freq=True, fps=60,
                           lat=0.15)
    # --- vision, latency (picture) -----------------------------------------
    out["resnet50-pic"] = _svc("resnet50-pic", 8.2, 25.6, lat=0.3)
    out["yolov11-pic"] = _svc("yolov11-pic", 20.0, 56.9, lat=0.3)
    out["deeplabv3p-pic"] = _svc("deeplabv3p-pic", 180.0, 62.7, lat=0.5)
    out["sctnet-pic"] = _svc("sctnet-pic", 90.0, 17.4, lat=0.4)
    # --- text, latency ----------------------------------------------------
    out["bert-cls"] = _svc("bert-cls", 45.0, 110.0, lat=0.3)
    out["gnmt-translate"] = _svc("gnmt-translate", 90.0, 278.0, lat=0.6)
    out["qwen2.5-1.5b-chat"] = _svc("qwen2.5-1.5b-chat",
                                    2 * 1.5 * 256, 1540.0, lat=1.5)
    # --- heavy (>1 GPU) ------------------------------------------------------
    if include_heavy:
        out["maskformer-seg"] = _svc("maskformer-seg", 700.0, 10500.0,
                                     lat=1.2, vram_extra=2.2)
        out["omgseg-seg"] = _svc("omgseg-seg", 1400.0, 19000.0, lat=1.6,
                                 vram_extra=2.2)
        # 1080p semantic segmentation is heavy enough that ONE GPU
        # undershoots the 60 fps SLO (the paper's Fig. 1: 49 fps) — this
        # is exactly where request-level DP binds
        out["deeplabv3p-vid"] = _svc("deeplabv3p-vid", 380.0, 62.7,
                                     freq=True, fps=60, lat=0.2)
        out["sctnet-vid"] = _svc("sctnet-vid", 260.0, 17.4, freq=True,
                                 fps=60, lat=0.2)
        out["llama3-8b-chat"] = _svc("llama3-8b-chat", 2 * 8.0 * 256,
                                     8000.0, lat=2.0, vram_extra=2.0)
        out["dsv2-16b-chat"] = _svc("dsv2-16b-chat", 2 * 2.4 * 256,
                                    15700.0, lat=2.0, vram_extra=2.0)
        out["qwen2.5-32b-chat"] = _svc("qwen2.5-32b-chat", 2 * 32.0 * 256,
                                       32500.0, lat=3.0, vram_extra=2.0)
        out["llama3-70b-hci"] = _svc("llama3-70b-hci", 2 * 70.0 * 16,
                                     70000.0, freq=True, fps=10, lat=1.0,
                                     vram_extra=1.8)
        out["qwen2.5-1.5b-hci"] = _svc("qwen2.5-1.5b-hci", 2 * 1.5 * 16,
                                       1540.0, freq=True, fps=30, lat=0.2)
    return out


@dataclasses.dataclass
class WorkloadConfig:
    horizon_s: float = 120.0
    load_scale: float = 1.0        # multiply all rates
    burstiness: float = 4.0        # CV^2 of inter-arrivals (Azure-like)
    stream_duration_s: float = 8.0  # frequency stream length
    freq_share: float = 0.5        # fraction of load that is streams
    seed: int = 0
    # prompt / shared-prefix structure of latency requests (templated
    # system prompts, like the Azure LLM traces): each arrival either
    # reuses one of ``prompt_templates`` per-service templates (sharing
    # the first ``template_tokens`` of its prompt with every other user
    # of that template) or carries a one-off prompt.  0 prompt tokens
    # disables prompt modeling entirely (legacy configs unchanged).
    prompt_tokens: int = 0         # total prompt length per latency request
    template_tokens: int = 0       # shared prefix length of a template
    prompt_templates: int = 4      # per-service template pool size
    template_repeat_p: float = 0.6  # P(arrival reuses a pool template)


def generate_requests(services: Dict[str, ServiceSpec],
                      num_servers: int,
                      cfg: WorkloadConfig) -> List[Tuple[float, int, Request]]:
    """Returns [(arrival_time, server_id, Request)] sorted by time.

    Latency services get Gamma-burst arrivals; frequency services get
    stream arrivals (each stream = duration * fps frames).  Rates are
    heavy-tailed across services (Zipf-ish, like the Azure trace)."""
    rng = np.random.default_rng(cfg.seed)
    events: List[Tuple[float, int, Request]] = []
    rid = 0
    names = list(services)
    # Zipf-weighted popularity
    weights = np.array([1.0 / (i + 1) ** 0.8 for i in range(len(names))])
    weights /= weights.sum()
    base_rate_per_server = 4.0 * cfg.load_scale

    for name, w in zip(names, weights):
        svc = services[name]
        for sid in range(num_servers):
            if svc.is_frequency:
                # stream arrivals: rate such that offered frames match share
                frames_per_stream = svc.slo_fps * cfg.stream_duration_s
                stream_rate = (base_rate_per_server * w * cfg.freq_share *
                               60.0 / frames_per_stream)
                n = rng.poisson(stream_rate * cfg.horizon_s)
                times = rng.uniform(0, cfg.horizon_s, size=n)
                for t in np.sort(times):
                    req = Request(rid=rid, service=name, arrival_s=t,
                                  frames=int(frames_per_stream),
                                  duration_s=cfg.stream_duration_s,
                                  deadline_s=t + svc.slo_latency_s,
                                  session=rid)
                    events.append((t, sid, req))
                    rid += 1
            else:
                rate = base_rate_per_server * w * (1 - cfg.freq_share) * 12
                shape = 1.0 / cfg.burstiness
                scale = 1.0 / max(rate, 1e-9) / shape
                t = 0.0
                while True:
                    t += rng.gamma(shape, scale)
                    if t >= cfg.horizon_s:
                        break
                    template = 0
                    if (cfg.prompt_tokens > 0 and cfg.prompt_templates > 0
                            and cfg.template_tokens > 0
                            and rng.random() < cfg.template_repeat_p):
                        template = 1 + int(rng.integers(cfg.prompt_templates))
                    req = Request(rid=rid, service=name, arrival_s=t,
                                  frames=1,
                                  prompt_tokens=cfg.prompt_tokens,
                                  template=template,
                                  deadline_s=t + svc.slo_latency_s)
                    events.append((t, sid, req))
                    rid += 1
    events.sort(key=lambda e: e[0])
    return events


def derive_prefix_hit_rates(events: Sequence[Tuple[float, int, Request]],
                            services: Dict[str, ServiceSpec],
                            cfg: WorkloadConfig) -> Dict[str, float]:
    """Expected per-service prefix-cache hit rate implied by the generated
    workload's ACTUAL template-repeat structure (not a hand-tuned scalar):
    walking arrivals in time order, the first use of a template on a
    server misses (the cache indexes it on eviction), every later reuse
    hits the template's shared ``template_tokens`` prefix.  The returned
    fraction is cached prompt tokens / total prompt tokens per service —
    exactly what the simulator's hit-rate discount prices, so placement
    sees the post-reuse prefill cost the live radix cache would deliver
    on this trace.  Services with no prompt structure map to 0.0."""
    hit: Dict[str, float] = {}
    total: Dict[str, float] = {}
    seen = set()                      # (service, server, template) indexed
    for _, sid, req in sorted(events, key=lambda e: e[0]):
        svc = services[req.service]
        if svc.is_frequency or req.prompt_tokens <= 0:
            continue
        total[req.service] = total.get(req.service, 0.0) + req.prompt_tokens
        if req.template:
            key = (req.service, sid, req.template)
            if key in seen:
                hit[req.service] = (hit.get(req.service, 0.0)
                                    + min(cfg.template_tokens,
                                          req.prompt_tokens))
            else:
                seen.add(key)
    return {name: (hit.get(name, 0.0) / tot if tot else 0.0)
            for name, tot in total.items()}


def demand_matrix(events: Sequence[Tuple[float, int, Request]],
                  services: Dict[str, ServiceSpec],
                  horizon_s: float) -> Dict[Tuple[str, int], float]:
    """Per-(service, server) offered rate (reqs or frames /sec) — the R^T
    input of the placement problem."""
    acc: Dict[Tuple[str, int], float] = {}
    for t, sid, req in events:
        svc = services[req.service]
        load = req.frames / req.duration_s if req.duration_s else 1.0
        key = (req.service, sid)
        if svc.is_frequency:
            acc[key] = acc.get(key, 0.0) + req.frames / horizon_s
        else:
            acc[key] = acc.get(key, 0.0) + 1.0 / horizon_s
    return acc
