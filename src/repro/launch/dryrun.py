import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) combination on the production meshes, print
memory_analysis / cost_analysis, and persist the roofline terms.

MUST be imported before any other jax-touching module — the two lines above
run before all imports so jax initializes with 512 placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
  python -m repro.launch.dryrun --all --both-meshes

Results land in one JSON per (arch, shape, mesh) so the sweep is
resumable; benchmarks/roofline reads these JSONs.
"""
import argparse       # noqa: E402
import json           # noqa: E402
import sys            # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, config_for_shape  # noqa: E402
from repro.launch import mesh as meshlib                   # noqa: E402
from repro.launch.steps import build_step, lower_step      # noqa: E402
from repro.roofline.analysis import (analyze_compiled,     # noqa: E402
                                     model_flops_estimate)
from repro.roofline.analytic import traffic                # noqa: E402


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            out_dir: str, fsdp_params: bool = True,
            pad_vocab: int = 0, serve_2d_tp: bool = False,
            microbatches: int = 0, variant: str = "",
            mesh_shape: str = "", act_shard: str = "auto",
            fuse_proj: bool = False, expert_parallel: bool = False,
            verbose: bool = True) -> dict:
    mesh_tag = "pod512" if multi_pod else "pod256"
    if mesh_shape:
        mesh_tag = "mesh" + mesh_shape.replace(",", "x")
    vtag = f"_{variant}" if variant else ""
    name = f"{arch}|{shape_name}|{mesh_tag}{vtag}"
    out_path = os.path.join(out_dir,
                            f"{arch}_{shape_name}_{mesh_tag}{vtag}.json")
    if os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    if mesh_shape:
        dims = tuple(int(x) for x in mesh_shape.split(","))
        mesh = meshlib.make_mesh(dims, ("data", "model")[:len(dims)]
                                 if len(dims) == 2
                                 else ("pod", "data", "model"))
    else:
        mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    asm = None if act_shard == "auto" else (act_shard == "on")
    if fuse_proj:
        import dataclasses as _dc
        import repro.configs as _C
        _C.ARCHS[arch] = _dc.replace(_C.ARCHS[arch],
                                     fused_projections=True)
    bundle = build_step(arch, shape_name, mesh, fsdp_params=fsdp_params,
                        pad_vocab_multiple=pad_vocab or None,
                        serve_2d_tp=serve_2d_tp,
                        act_shard_model=asm,
                        expert_parallel=expert_parallel,
                        microbatches=microbatches or None)
    lowered = lower_step(bundle)
    t_lower = time.time() - t0
    hlo_text = lowered.as_text()
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"[{name}] memory_analysis: {mem}")
        interesting = {k: v for k, v in (cost or {}).items()
                       if k in ("flops", "bytes accessed")}
        print(f"[{name}] cost_analysis: {interesting}")

    cfg = config_for_shape(arch, shape_name)
    shape = SHAPES_BY_NAME[shape_name]
    pod_ax = mesh.shape.get("pod", 1)
    tb = traffic(cfg, shape, data_ax=mesh.shape["data"],
                 model_ax=mesh.shape["model"], pod_ax=pod_ax,
                 microbatches=bundle.microbatches,
                 optimizer=(bundle.optimizer if bundle.optimizer != "none"
                            else "adamw"),
                 fsdp=fsdp_params, serve_2d_tp=serve_2d_tp)
    roof = analyze_compiled(name, compiled, chips,
                            model_flops=model_flops_estimate(cfg, shape),
                            hlo_text=compiled.as_text(),
                            analytic_traffic=tb)
    hbm_used = (float(getattr(mem, "argument_size_in_bytes", 0))
                + float(getattr(mem, "temp_size_in_bytes", 0))
                + float(getattr(mem, "output_size_in_bytes", 0))
                - float(getattr(mem, "alias_size_in_bytes", 0)))
    record = dict(
        roof.to_dict(), arch=arch, shape=shape_name, mesh=mesh_tag,
        hbm_used_bytes=hbm_used, fits_hbm=bool(hbm_used <= 16e9),
        step=bundle.name, lower_s=t_lower, compile_s=t_compile,
        long_context_variant=(shape_name == "long_500k"
                              and cfg.sliding_window is not None
                              and config_for_shape(arch, "train_4k")
                              .sliding_window is None),
        ok=True)
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        print(f"[{name}] compute={roof.compute_s:.4g}s "
              f"memory={roof.memory_s:.4g}s coll={roof.collective_s:.4g}s "
              f"dominant={roof.dominant} useful={roof.useful_flops_ratio:.3f}"
              f" (lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate weight rows over data axis (pure-TP)")
    ap.add_argument("--pad-vocab", type=int, default=0,
                    help="pad vocab_size to a multiple (hillclimb)")
    ap.add_argument("--serve-2d-tp", action="store_true",
                    help="decode with replicated batch / 2D-TP weights")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--variant", default="",
                    help="tag for the output filename (hillclimb runs)")
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh, e.g. 4,64 (data,model)")
    ap.add_argument("--act-shard", default="auto",
                    choices=["auto", "on", "off"])
    ap.add_argument("--fuse-proj", action="store_true",
                    help="fused QKV + gate|up projections (hillclimb)")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="shard MoE experts over the model axis (hillclimb)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES_BY_NAME:
                combos.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for multi_pod in meshes:
        for arch, shape in combos:
            try:
                run_one(arch, shape, multi_pod=multi_pod, out_dir=args.out,
                        fsdp_params=not args.no_fsdp,
                        pad_vocab=args.pad_vocab,
                        serve_2d_tp=args.serve_2d_tp,
                        microbatches=args.microbatches,
                        variant=args.variant, mesh_shape=args.mesh_shape,
                        act_shard=args.act_shard,
                        fuse_proj=args.fuse_proj,
                        expert_parallel=args.expert_parallel)
            except Exception as e:   # noqa: BLE001 — record and continue
                traceback.print_exc()
                failures.append((arch, shape, multi_pod, repr(e)[:200]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_)
        return 1
    print(f"\nall {len(combos) * len(meshes)} combos lowered + compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
