"""Serving launcher: deploy services through the EPARA control plane and
drive batched requests end-to-end (the paper-kind driver).

Each "edge server" is a ServiceRuntime deployment; the EPARA allocator
picks (MP, BS, MT, MF, DP) per service, the SSSP placement assigns services
to servers, and the distributed handler routes every request (local first,
then idle-goodput-weighted offload).  On CPU the models are reduced
variants; on TPU the same engine takes pjit'd step functions.

  PYTHONPATH=src python -m repro.launch.serve --archs minicpm-2b,mamba2-2.7b \
      --servers 3 --requests 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core import (EdgeCloudControlPlane, GPUSpec, Outcome, Request,
                        ServerSpec, ServiceSpec, Sensitivity, allocate)
from repro.core.faults import FaultInjector, FaultSpec, random_fault_spec
from repro.models.registry import model_api
from repro.serving.engine import (PREFIX_CACHEABLE_FAMILIES,
                                  EparaServingEngine, GenerationRequest,
                                  ServiceRuntime)
from repro.serving.failover import ClusterSupervisor, RetryPolicy


def service_spec_for(cfg) -> ServiceSpec:
    return ServiceSpec(
        name=cfg.name,
        flops_per_request=2.0 * cfg.active_param_count() * 64,
        weights_bytes=cfg.param_count() * 2.0,
        vram_bytes=cfg.param_count() * 2.0 * 1.5 + 5e8,
        sensitivity=Sensitivity(cfg.epara_sensitivity),
        slo_latency_s=2.0, slo_fps=20.0 if
        cfg.epara_sensitivity == "frequency" else 0.0,
        arch=cfg.name, stateful=cfg.family in ("ssm", "hybrid"),
        prefix_cacheable=cfg.family in PREFIX_CACHEABLE_FAMILIES)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="minicpm-2b,mamba2-2.7b")
    ap.add_argument("--servers", type=int, default=3)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=("continuous", "sync"),
                    default="continuous",
                    help="serving data plane: slot-based continuous "
                         "batching (default) or run-to-completion batches")
    ap.add_argument("--kvcache-impl", choices=("paged", "dense"),
                    default="paged",
                    help="cache data plane: fixed-capacity paged KV arena "
                         "(default; one decode compile, zero-copy "
                         "admissions) or the legacy dense merge path")
    ap.add_argument("--max-seq-len", type=int, default=256,
                    help="per-slot token budget the paged arena is sized "
                         "for (prompt + max_new_tokens)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="disable chunked piggybacked prefill (prompts "
                         "then prefill in one shot at admission, stalling "
                         "live decode slots and retracing per prompt "
                         "length)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunk bucket size in tokens (0 = the plan's "
                         "category-derived default: small for latency "
                         "services, large for frequency services)")
    ap.add_argument("--block-size", type=int, default=32,
                    help="paged-arena block size in tokens (the prefix "
                         "cache's sharing granularity)")
    ap.add_argument("--prefix-cache", type=int, default=-1,
                    help="radix prefix-cache retention: -1 = the plan's "
                         "category-derived bound (frequency retains "
                         "aggressively, latency bounded), 0 = disabled, "
                         ">0 = max idle cached blocks")
    ap.add_argument("--kv-dtype", default="auto",
                    help="paged-KV pool precision: 'auto' = the plan's "
                         "category-derived choice (frequency services "
                         "quantize blocks to int8 with per-row scales, "
                         "latency services keep the model dtype), or an "
                         "explicit 'bf16'/'int8' override for every "
                         "service")
    ap.add_argument("--admission-policy", choices=("fifo", "sdf"),
                    default="fifo",
                    help="admission control: arrival-order fifo (default) "
                         "or strictest-deadline-first — slack-ordered "
                         "queues, explicit reject verdicts, and preemption "
                         "of lazy decodes by block-table parking")
    ap.add_argument("--no-preempt", action="store_true",
                    help="with --admission-policy=sdf, disable block-table "
                         "parking (shed-only admission control)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request completion deadline in seconds from "
                         "submission (0 = none); with sdf admission, "
                         "requests that cannot make it are rejected with "
                         "a verdict instead of served dead")
    ap.add_argument("--speculate", type=int, default=-1,
                    help="speculative decoding draft depth k: -1 = the "
                         "plan's category-derived choice (latency "
                         "services speculate when a draft is given, "
                         "frequency services don't), 0 = disabled, >0 = "
                         "propose k tokens per fused verify launch "
                         "(requires --draft-arch)")
    ap.add_argument("--draft-arch", default="",
                    help="arch id of the small draft model that proposes "
                         "tokens for speculative decoding; must share "
                         "family and vocab with the target service "
                         "(incompatible services deploy non-speculative)")
    ap.add_argument("--n-samples", type=int, default=1,
                    help="parallel samples per request: n-1 sibling slots "
                         "fork off the prompt's blocks by refcount and "
                         "diverge copy-on-write (capped by the plan's "
                         "category-derived resolved_n_samples; >1 is "
                         "only diverse with a stochastic sampler)")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome-trace-event JSON of every "
                         "request's lifecycle spans and the engine's "
                         "per-step phases to this path (load in Perfetto "
                         "or chrome://tracing); default off — the tracer "
                         "is byte-inert either way")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry to this path at the "
                         "end of the run: Prometheus text exposition, or "
                         "a JSONL snapshot when the path ends in .jsonl")
    ap.add_argument("--calibrate-out", default="",
                    help="fold the run's measured telemetry (speculative "
                         "acceptance, prefix hit rates, prefill cost) "
                         "into SimConfig overrides and write the "
                         "calibration report JSON to this path")
    ap.add_argument("--fault-spec", default="",
                    help="replay a deterministic fault schedule from this "
                         "JSON file (core/faults.py FaultSpec) against "
                         "the run: crashes/restarts, stragglers, digest "
                         "corruption, dropped offload handoffs")
    ap.add_argument("--chaos-seed", type=int, default=-1,
                    help="generate a random (but seed-deterministic) "
                         "fault schedule instead of --fault-spec; -1 = "
                         "no injected faults (default)")
    ap.add_argument("--chaos-horizon-s", type=float, default=20.0,
                    help="time horizon the generated --chaos-seed "
                         "schedule spreads its events over (logical "
                         "rounds unless --admission-policy=sdf)")
    ap.add_argument("--retry-timeout-s", type=float, default=8.0,
                    help="base offload/handoff timeout before a request "
                         "retries on the next-best peer (exponential "
                         "backoff per attempt)")
    ap.add_argument("--retry-max-attempts", type=int, default=4,
                    help="placement attempts per request before a dead "
                         "avenue draws an explicit FAILED verdict")
    ap.add_argument("--pjit-decode", action="store_true",
                    help="build each service's fused paged decode step "
                         "under pjit on a (1, device_count) service mesh "
                         "(data, model) — the MP-sharded zero-gather "
                         "path; on one CPU device this is a trivial mesh "
                         "but exercises the same build")
    args = ap.parse_args(argv)

    # mirror the engine's knob validation at the flag boundary so a bad
    # value fails with a usage error instead of a deep ValueError
    if args.block_size < 1:
        ap.error(f"--block-size must be positive, got {args.block_size}")
    if args.prefill_chunk < 0 or (args.prefill_chunk
                                  and args.prefill_chunk % args.block_size):
        ap.error(f"--prefill-chunk must be 0 (category default) or a "
                 f"positive multiple of --block-size={args.block_size}, "
                 f"got {args.prefill_chunk}")
    if args.prefix_cache < -1:
        ap.error(f"--prefix-cache must be -1 (category default), 0 "
                 f"(disabled) or a positive block count, got "
                 f"{args.prefix_cache}")
    if args.kv_dtype not in ("auto", "bf16", "int8"):
        ap.error(f"--kv-dtype must be auto (category default), bf16 or "
                 f"int8, got {args.kv_dtype!r}")
    if args.kv_dtype == "int8" and args.kvcache_impl != "paged":
        ap.error("--kv-dtype=int8 requires --kvcache-impl=paged (only "
                 "page pools are block-quantized)")
    if args.admission_policy != "fifo" and args.mode != "continuous":
        ap.error("--admission-policy=sdf requires --mode=continuous (the "
                 "controller acts between composer and slot engine)")
    if args.deadline_s < 0:
        ap.error(f"--deadline-s must be >= 0, got {args.deadline_s}")
    if args.speculate < -1:
        ap.error(f"--speculate must be -1 (category default), 0 "
                 f"(disabled) or a positive draft depth, got "
                 f"{args.speculate}")
    if args.speculate > 0 and not args.draft_arch:
        ap.error("--speculate > 0 requires --draft-arch (the model that "
                 "proposes the k tokens)")
    if args.draft_arch and args.draft_arch not in ARCH_IDS:
        ap.error(f"unknown --draft-arch {args.draft_arch!r}")
    if args.draft_arch and (args.mode != "continuous"
                            or args.kvcache_impl != "paged"
                            or args.no_chunked_prefill):
        ap.error("--draft-arch requires --mode=continuous, "
                 "--kvcache-impl=paged and chunked prefill (the draft "
                 "cache is chased through the paged chunk path)")
    if args.n_samples < 1:
        ap.error(f"--n-samples must be >= 1, got {args.n_samples}")
    if args.fault_spec and args.chaos_seed >= 0:
        ap.error("--fault-spec and --chaos-seed are mutually exclusive "
                 "(a replayed schedule IS the seed's output)")
    if args.retry_timeout_s <= 0:
        ap.error(f"--retry-timeout-s must be positive, got "
                 f"{args.retry_timeout_s}")
    if args.retry_max_attempts < 1:
        ap.error(f"--retry-max-attempts must be >= 1, got "
                 f"{args.retry_max_attempts}")
    kv_dtype = -1 if args.kv_dtype == "auto" else args.kv_dtype

    arch_ids = [a.strip() for a in args.archs.split(",")]
    for a in arch_ids:
        assert a in ARCH_IDS, f"unknown arch {a}"

    # control plane: EPARA allocator + placement + handler
    servers = [ServerSpec(sid=i, num_gpus=4) for i in range(args.servers)]
    specs = {}
    cfgs = {}
    for a in arch_ids:
        full = get_config(a)
        specs[a] = service_spec_for(full)
        cfgs[a] = reduced(full)          # CPU-sized data plane
    cp = EdgeCloudControlPlane(servers, specs)
    demand = {(a, s.sid): 4.0 for a in arch_ids for s in servers}
    placements = cp.run_placement(demand)
    print("EPARA plans:")
    for a, plan in cp.plans.items():
        kv = plan.resolved_kv_dtype() if kv_dtype == -1 else kv_dtype
        print(f"  {a:20s} {plan.category} mp={plan.mp} bs={plan.bs} "
              f"mt={plan.mt} mf={plan.mf} dp={plan.dp} kv={kv}")
    print(f"placements: {placements}")

    # data plane: one engine per server, reduced models
    engines = {s.sid: EparaServingEngine() for s in servers}
    # observability (repro/obs): one tracer + one registry shared by every
    # runtime — service names become trace processes / metric labels.
    # Default off; enabled it is still byte-inert (asserted by the tests)
    tracer = metrics = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    rng = np.random.default_rng(args.seed)
    import dataclasses as _dc
    step_builder = None
    if args.pjit_decode:
        # MP-sharded paged decode: the same pure fused step, jitted with
        # the service mesh's shardings (launch/steps.paged_decode_builder)
        from repro.launch import mesh as meshlib
        from repro.launch.steps import paged_decode_builder
        service_mesh = meshlib.make_mesh((1, jax.device_count()),
                                         ("data", "model"))
        step_builder = paged_decode_builder(service_mesh)
    draft_cfg = draft_params = None
    if args.draft_arch:
        draft_cfg = reduced(get_config(args.draft_arch))
        draft_params = model_api(draft_cfg).init(
            jax.random.PRNGKey(hash(args.draft_arch) % 2**31), draft_cfg)
    for svc, sid in placements:
        if sid < 0:
            continue
        cfg = cfgs[svc]
        params = model_api(cfg).init(jax.random.PRNGKey(hash(svc) % 2**31),
                                     cfg)
        chunked = (None if not args.no_chunked_prefill else False)
        # the draft only pairs with same-family same-vocab attention
        # services; the rest deploy non-speculative (an explicit
        # --speculate > 0 still reaches the engine's loud gate)
        compat = (draft_cfg is not None
                  and cfg.family == draft_cfg.family
                  and cfg.vocab_size == draft_cfg.vocab_size
                  and cfg.family in PREFIX_CACHEABLE_FAMILIES)
        if draft_cfg is not None and not compat and args.speculate <= 0:
            print(f"  note: {svc} incompatible with draft "
                  f"{args.draft_arch} (family/vocab) — non-speculative")
        plan = _dc.replace(cp.plans[svc], prefix_cache=args.prefix_cache,
                           kv_dtype=kv_dtype,
                           admission=args.admission_policy,
                           speculate=args.speculate)
        rt = ServiceRuntime(cfg, params, plan, mode=args.mode,
                            kvcache_impl=args.kvcache_impl,
                            max_seq_len=args.max_seq_len,
                            block_size=args.block_size,
                            chunked_prefill=chunked,
                            prefill_chunk=(args.prefill_chunk or None),
                            paged_step_builder=step_builder,
                            preempt=not args.no_preempt,
                            draft_params=draft_params if compat else None,
                            draft_cfg=draft_cfg if compat else None,
                            tracer=tracer, metrics=metrics)
        engines[sid].deploy(svc, rt)

    # drive requests through handler -> engine, supervised: the
    # ClusterSupervisor owns the ledger (every rid ends served or
    # verdicted), the deadline-derived offload retry timeouts, and —
    # when a fault schedule is given — crash evacuation + failover
    cp.publish_all(0.0)
    for _ in range(len(servers)):
        cp.sync_step(0.0)
    # monotonic, not wall-clock: deadlines and throughput math must not
    # jump when NTP slews the system clock mid-run
    t0 = time.monotonic()
    # the data-plane clock: seconds since t0 — GenerationRequest deadlines
    # and the admission controller's slack estimates live in this frame
    deadline = args.deadline_s
    fault_spec = None
    if args.fault_spec:
        with open(args.fault_spec) as f:
            fault_spec = FaultSpec.from_json(f.read())
    elif args.chaos_seed >= 0:
        fault_spec = random_fault_spec(
            [s.sid for s in servers], args.chaos_horizon_s,
            seed=args.chaos_seed)
    if fault_spec is not None:
        print(f"fault schedule ({len(fault_spec.events)} events): "
              + ", ".join(f"{e.kind}@{e.at_s:.1f}s->s{e.sid}"
                          for e in fault_spec.events))
    supervisor = ClusterSupervisor(
        cp, engines,
        retry=RetryPolicy(base_timeout_s=args.retry_timeout_s,
                          max_attempts=args.retry_max_attempts),
        injector=FaultInjector(fault_spec) if fault_spec else None,
        metrics=metrics, tracer=tracer)
    for i in range(args.requests):
        svc = arch_ids[i % len(arch_ids)]
        at = int(rng.integers(0, len(servers)))
        cfg = cfgs[svc]
        prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
        extras = None
        if cfg.family in ("audio", "vlm"):
            dim = cfg.encoder_len if cfg.family == "audio" else cfg.prefix_len
            extras = {"embeddings": np.zeros((dim, cfg.d_model), np.float32)}
        supervisor.submit(svc, GenerationRequest(
            rid=i, tokens=prompt, max_new_tokens=args.max_new_tokens,
            stream=i, extras=extras, n_samples=args.n_samples,
            deadline_s=deadline if deadline else 0.0), at_server=at,
            now=0.0)
    clock = ((lambda: time.monotonic() - t0)
             if args.admission_policy == "sdf" else None)
    report = supervisor.run_until_idle(clock=clock)
    results = report.results
    outcomes = report.outcomes
    dt = time.monotonic() - t0
    toks = sum(len(r.tokens) for r in results)
    steps = sum(rt.decode_steps for eng in engines.values()
                for rt in eng.runtimes.values())
    traces = sum(rt.decode_traces for eng in engines.values()
                 for rt in eng.runtimes.values())
    copies = sum(rt.whole_cache_copies for eng in engines.values()
                 for rt in eng.runtimes.values())
    copy_mb = sum(rt.admission_copy_bytes for eng in engines.values()
                  for rt in eng.runtimes.values()) / 1e6
    print(f"served {len(results)}/{args.requests} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s, {steps} fused decode steps, "
          f"mode={args.mode}, kvcache={args.kvcache_impl})  "
          f"outcomes={outcomes}")
    rts = [rt for eng in engines.values() for rt in eng.runtimes.values()]
    chunk_calls = sum(rt.prefill_chunk_calls for rt in rts)
    pf_traces = sum(rt.prefill_traces for rt in rts)
    chunk_mb = sum(rt.chunk_write_bytes for rt in rts) / 1e6
    native = sum(rt.paged_native for rt in rts)
    print(f"data plane: {traces} decode compiles, {pf_traces} prefill "
          f"compiles, {chunk_calls} prefill chunks, {copies} whole-cache "
          f"admission copies, {copy_mb:.2f} MB admission-copy bytes, "
          f"{chunk_mb:.2f} MB chunk writes, {native}/{len(rts)} "
          f"zero-gather paged-native services")
    hit_toks = sum(rt.prefix_hit_tokens for rt in rts)
    computed = sum(rt.prefill_tokens_computed for rt in rts)
    print(f"prefix cache: {sum(rt.prefix_hits for rt in rts)} hits, "
          f"{hit_toks} prompt tokens reused, {computed} computed, "
          f"{sum(rt.prefix_cow_copies for rt in rts)} COW copies, "
          f"{sum(rt.prefix_evictions for rt in rts)} LRU evictions, "
          f"{sum(rt.oneshot_prefills for rt in rts)} one-shot prefills")
    ver = sum(rt.verify_launches for rt in rts)
    acc = sum(rt.accepted_tokens for rt in rts)
    if ver or args.draft_arch:
        per = acc / ver if ver else 0.0
        print(f"speculative (draft={args.draft_arch or 'none'}): {ver} "
              f"verify launches, {acc} tokens accepted "
              f"({per:.2f}/launch), "
              f"{sum(rt.draft_steps for rt in rts)} draft steps, "
              f"{sum(rt.spec_degraded for rt in rts)} degraded, "
              f"{sum(rt.verify_traces for rt in rts)} verify compiles")
    forks = sum(rt.forks_spawned for rt in rts)
    if forks or args.n_samples > 1:
        print(f"parallel sampling (n={args.n_samples}): {forks} forks "
              f"spawned, {sum(rt.fork_shortfall for rt in rts)} shortfall")
    verdicts = {}
    for rt in rts:
        for v, n in rt.admission.verdicts.items():
            verdicts[v] = verdicts.get(v, 0) + n
    print(f"admission ({args.admission_policy}): {verdicts or 'no verdicts'}"
          f", {sum(rt.admission.preemptions for rt in rts)} preemptions, "
          f"{sum(rt.admission.resumes for rt in rts)} resumes, "
          f"{report.offload_retries} offload/timeout retries, "
          f"{len(report.rejects)} final rejects")
    if fault_spec is not None or report.failovers or report.duplicates:
        print(f"fault tolerance: {report.failovers} crash failovers, "
              f"{report.evacuated} requests evacuated, "
              f"{report.duplicates} duplicate completions deduplicated, "
              f"{report.dropped_offloads} handoffs dropped, "
              f"{report.heartbeat_misses} straggler rounds skipped, "
              f"{sum(rt.evacuations for rt in rts)} runtime evacuations")
    if tracer is not None:
        tracer.export(args.trace_out)
        print(f"trace: {tracer.emitted} events "
              f"({tracer.dropped} dropped by the ring) -> {args.trace_out}")
    if metrics is not None:
        if args.metrics_out.endswith(".jsonl"):
            metrics.append_jsonl(args.metrics_out)
        else:
            metrics.write_prometheus(args.metrics_out)
        print(f"metrics: {len(metrics._metrics)} series -> "
              f"{args.metrics_out}")
    if args.calibrate_out:
        from repro.obs import (merge_telemetry, telemetry_from_runtime,
                               write_calibration)
        tel = merge_telemetry(
            telemetry_from_runtime(name, rt)
            for eng in engines.values()
            for name, rt in eng.runtimes.items())
        cal = write_calibration(args.calibrate_out, tel)
        print(f"calibration: spec_accept_rate={cal.spec_accept_rate:.3f} "
              f"prefix_hit_rates={cal.prefix_hit_rates or {}} "
              f"prefill_token_s={cal.prefill_token_s:.2e} -> "
              f"{args.calibrate_out}")
    # every request is accounted for: served, or rejected with a verdict
    return 0 if report.accounted == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
