"""Training launcher.

On real TPU hardware this drives the full assigned configs over the
production mesh; on CPU (this container) it runs reduced variants of the
same families end-to-end — the quickstart trains a ~100M-param model for a
few hundred steps with the identical code path (steps.build_step is only
needed for the sharded deployment; here we jit directly).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-2.7b \
      --reduced --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.data.pipeline import TokenPipeline
from repro.models.registry import input_specs, model_api
from repro.training import checkpoint
from repro.training.optimizer import get_optimizer
from repro.training.train_step import make_train_step


def build_batch(cfg, tokens, labels):
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    B = tokens.shape[0]
    if cfg.family == "audio":
        batch["embeddings"] = jnp.zeros(
            (B, cfg.encoder_len, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "vlm":
        batch["embeddings"] = jnp.zeros(
            (B, cfg.prefix_len, cfg.d_model), cfg.compute_dtype)
    return batch


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-sized variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override reduced d_model (e.g. 512 for ~100M)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over = dict(d_model=args.d_model, num_heads=args.d_model // 64,
                        num_kv_heads=max(1, args.d_model // 128),
                        head_dim=64, d_ff=args.d_model * 3,
                        vocab_size=4096)
        if args.layers:
            over["num_layers"] = args.layers
        cfg = reduced(cfg, **over)
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    opt = get_optimizer(args.optimizer, args.lr)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      num_microbatches=args.microbatches))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch_size=args.batch, seed=0)

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        raw = pipe.batch(step)
        batch = build_batch(cfg, raw["tokens"] % cfg.vocab_size,
                            raw["labels"] % cfg.vocab_size)
        params, state, metrics = step_fn(params, state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tps = (step + 1) * args.batch * args.seq / dt
            print(f"step {step:5d} loss {losses[-1]:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"({tps:8.0f} tok/s)")
    if args.checkpoint:
        path = checkpoint.save(args.checkpoint, params, step=args.steps)
        print(f"checkpoint -> {path}")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
