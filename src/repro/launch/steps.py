"""Step builders: one compiled function per (arch x input-shape x mesh).

``build_step`` returns a StepBundle with the jitted function, the
ShapeDtypeStruct argument tree (no device allocation), and the
in/out shardings — exactly what dryrun.py lowers and what train.py /
serve.py execute on real hardware.

Shape -> step mapping:
  train_4k               -> train_step (loss + grads + optimizer update)
  prefill_32k            -> serve_prefill (logits of last position + cache)
  decode_32k / long_500k -> serve_decode (ONE token vs a seq_len cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import config_for_shape
from repro.kernels.quant import QuantPages
from repro.models.config import ModelConfig, SHAPES_BY_NAME, ShapeSpec
from repro.models.registry import input_specs, model_api
from repro.training.optimizer import get_optimizer
from repro.training.train_step import make_train_step

from . import mesh as meshlib

ADAFACTOR_THRESHOLD = 50e9     # params above this train with adafactor


def choose_optimizer(cfg: ModelConfig) -> str:
    return "adafactor" if cfg.param_count() > ADAFACTOR_THRESHOLD \
        else "adamw"


def choose_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                        batch_shards: int = 16) -> int:
    if shape.kind != "train":
        return 1
    if cfg.param_count() > ADAFACTOR_THRESHOLD:
        # MoE dispatch/combine transients scale with the microbatch; 16
        # keeps grok-314b near the 16 GB/chip HBM line (§Dry-run)
        k = 16
    elif cfg.param_count() > 5e9:
        k = 4
    else:
        k = 2
    # each microbatch must still shard evenly over the batch axes — on the
    # 512-chip mesh (32 batch shards) k=16 would leave 16-row microbatches
    # replicated across pods (observed +7 GB/chip, EXPERIMENTS.md §Dry-run)
    while k > 1 and (shape.global_batch // k) % batch_shards != 0:
        k //= 2
    return k


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable                     # jitted (already wrapped with shardings)
    args: Tuple[Any, ...]            # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    cfg: ModelConfig
    shape: ShapeSpec
    mesh: Mesh
    microbatches: int = 1
    optimizer: str = "none"


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_step(arch: str, shape_name: str, mesh: Mesh, *,
               fsdp_params: bool = True,
               microbatches: Optional[int] = None,
               optimizer_name: Optional[str] = None,
               pad_vocab_multiple: Optional[int] = None,
               serve_2d_tp: bool = False,
               act_shard_model: Optional[bool] = None,
               expert_parallel: bool = False,
               impl: Optional[str] = None) -> StepBundle:
    cfg = config_for_shape(arch, shape_name)
    if pad_vocab_multiple:
        # §Perf hillclimb: pad the vocab so the lm-head/embedding shard
        # over the model axis (minicpm's 122753 is unshardable -> full
        # f32 logits all-reduced per loss chunk)
        v = -(-cfg.vocab_size // pad_vocab_multiple) * pad_vocab_multiple
        cfg = dataclasses.replace(cfg, vocab_size=v)
    shape = SHAPES_BY_NAME[shape_name]
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)

    params_shape = jax.eval_shape(lambda k: api.init(k, cfg), key)
    pspecs = meshlib.param_specs(mesh, params_shape, fsdp=fsdp_params,
                                 expert_parallel=expert_parallel)
    psharding = meshlib.named(mesh, pspecs)

    batch = input_specs(cfg, shape)
    if act_shard_model is None:
        # d-sharded carries only pay off when remat storage is the binding
        # constraint (the 100B+ models); small models lose more to the
        # reshard collectives than they save (EXPERIMENTS.md §Perf)
        act_shard_model = cfg.param_count() > ADAFACTOR_THRESHOLD
    meshlib.set_activation_mesh(mesh, shard_model=act_shard_model)

    if shape.kind == "train":
        opt_name = optimizer_name or choose_optimizer(cfg)
        opt = get_optimizer(opt_name)
        batch_shards = 1
        for ax in ("pod", "data"):
            batch_shards *= mesh.shape.get(ax, 1)
        nmb = microbatches or choose_microbatches(cfg, shape, batch_shards)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = meshlib.opt_state_specs(mesh, opt_shape, pspecs)
        osharding = meshlib.named(mesh, ospecs)
        bspecs = meshlib.batch_specs(mesh, batch)
        bsharding = meshlib.named(mesh, bspecs)
        # bf16 grad accumulation for 100B+ configs: the fp32 accumulator
        # chain alone (grads + moments + update temps) would exceed
        # 16 GB/chip on the single pod (EXPERIMENTS.md §Dry-run)
        accum = jnp.bfloat16 if cfg.param_count() > ADAFACTOR_THRESHOLD \
            else jnp.float32
        step = make_train_step(cfg, opt, num_microbatches=nmb,
                               accum_dtype=accum, impl=impl)
        out_shardings = (psharding, osharding, None)
        fn = jax.jit(step,
                     in_shardings=(psharding, osharding, bsharding),
                     out_shardings=out_shardings,
                     donate_argnums=(0, 1))
        args = (params_shape, opt_shape, batch)
        in_sh = (psharding, osharding, bsharding)
        name = f"{arch}:{shape_name}:train[{opt_name},mb={nmb}]"
    elif shape.kind == "prefill":
        bspecs = meshlib.batch_specs(mesh, batch)
        bsharding = meshlib.named(mesh, bspecs)
        cache_size = shape.seq_len

        def prefill(params, b):
            return api.prefill(params, cfg, b, cache_size=cache_size,
                               impl=impl)

        cache_shape = jax.eval_shape(
            lambda: api.init_cache(cfg, shape.global_batch, cache_size))
        cspecs = meshlib.cache_specs(mesh, cache_shape)
        csharding = meshlib.named(mesh, cspecs)
        out_shardings = (None, csharding)
        fn = jax.jit(prefill, in_shardings=(psharding, bsharding),
                     out_shardings=out_shardings)
        args = (params_shape, batch)
        in_sh = (psharding, bsharding)
        name = f"{arch}:{shape_name}:prefill"
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
        # the cache arrives "full": len = seq_len
        cspecs = meshlib.cache_specs(mesh, cache_shape,
                                     replicate_batch=serve_2d_tp)
        csharding = meshlib.named(mesh, cspecs)
        token = batch["token"]
        tspec = meshlib.batch_specs(mesh, {"token": token},
                                    replicate_batch=serve_2d_tp)["token"]
        tsharding = NamedSharding(mesh, tspec)

        def decode(params, tok, cache):
            return api.decode_step(params, cfg, tok, cache, impl=impl)

        out_shardings = (None, csharding)
        fn = jax.jit(decode,
                     in_shardings=(psharding, tsharding, csharding),
                     out_shardings=out_shardings,
                     donate_argnums=(2,))
        args = (params_shape, token, cache_shape)
        in_sh = (psharding, tsharding, csharding)
        name = f"{arch}:{shape_name}:decode" + \
            ("[2dtp]" if serve_2d_tp else "")

    return StepBundle(name=name, fn=fn, args=args, in_shardings=in_sh,
                      out_shardings=out_shardings, cfg=cfg, shape=shape,
                      mesh=mesh,
                      microbatches=nmb if shape.kind == "train" else 1,
                      optimizer=(opt_name if shape.kind == "train"
                                 else "none"))


def lower_step(bundle: StepBundle):
    with bundle.mesh:
        return bundle.fn.lower(*bundle.args)


# ---------------------------------------------------------------------------
# serving: pjit'd paged decode (MP-sharded zero-gather hot loop)
# ---------------------------------------------------------------------------

def _paged_leaf_spec(mesh: Mesh, leaf):
    """PartitionSpec for one arena device buffer.  Page pools
    ``(layers, pages, block_size, Hkv, D)`` and attention-shaped state
    shard their head/head_dim axes over the model axis — the same
    placement ``meshlib.cache_specs`` gives the dense cache — while the
    PAGE axis stays replicated (the block-table page indirection must
    resolve locally; model parallelism splits heads, not the pool).
    Smaller state leaves shard their channel axis when divisible."""
    nd = leaf.ndim
    if nd >= 4:
        prefs: Dict[Any, list] = {"model": [nd - 2, nd - 1]}
    elif nd >= 3:
        prefs = {"model": [nd - 1]}
    else:
        prefs = {}
    return meshlib._pick(mesh, tuple(leaf.shape), prefs)


def _pool_sharding(mesh: Mesh, pool):
    """Sharding(s) for one page pool.  Quantized pools are a two-leaf
    pytree: the int8 values shard like a dense pool (heads/head_dim over
    ``model``), the per-row scale pool ``(layers, pages, block_size, Hkv)``
    shards only its trailing Hkv axis — the same head placement as the
    values, never the token axis."""
    if isinstance(pool, QuantPages):
        vspec = _paged_leaf_spec(mesh, pool.values)
        sspec = meshlib._pick(mesh, tuple(pool.scales.shape),
                              {"model": [pool.scales.ndim - 1]})
        return QuantPages(NamedSharding(mesh, vspec),
                          NamedSharding(mesh, sspec))
    return NamedSharding(mesh, _paged_leaf_spec(mesh, pool))


def paged_decode_builder(mesh: Mesh, *, fsdp_params: bool = False):
    """Builder for ``ServiceRuntime(paged_step_builder=...)``: jits the
    engine's pure fused paged decode step under the service mesh so
    MP-sharded paged decode works — params shard by the standard rules,
    page pools / per-slot state shard their head axes over ``model``,
    and the host-fed control operands (tokens, lens, live, block tables)
    replicate.  The paged-native zero-gather step and the dense-view
    fallback both build this way; the arena's donated buffers still
    update in place under pjit."""

    def builder(runtime, arena):
        params_shape = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            runtime.params)
        psharding = meshlib.named(mesh, meshlib.param_specs(
            mesh, params_shape, fsdp=fsdp_params))
        pages_sh = [_pool_sharding(mesh, p) for p in arena.pages]
        state_sh = [NamedSharding(mesh, _paged_leaf_spec(mesh, s))
                    for s in arena.state]
        rep = NamedSharding(mesh, P())
        return jax.jit(
            runtime._paged_decode_pure(arena),
            in_shardings=(psharding, rep, pages_sh, state_sh, rep, rep,
                          rep),
            donate_argnums=arena._donate_argnums((2, 3, 4)))

    return builder
