"""Production meshes + sharding rules for every (arch x shape) step.

Meshes (TPU v5e target):
  single-pod : (16, 16)      -> ("data", "model")      = 256 chips
  multi-pod  : (2, 16, 16)   -> ("pod", "data", "model") = 512 chips

The ``pod`` axis only ever carries batch/replica parallelism — inter-pod
DCN is the analogue of EPARA's inter-edge-server links, and EPARA's own S2
rule ("keep multi-GPU parallel services inside one server") maps to
keeping model parallelism inside a pod (DESIGN.md §4).

Sharding policy (baseline; hillclimbs recorded in EXPERIMENTS.md §Perf):
  weights    : 2D — rows on ``data`` (ZeRO/FSDP-style), cols on ``model``.
  batch      : ("pod","data") on the leading batch dim.
  activations: block-boundary constraint (batch, None, "model") so the
               remat-scan carries stay sharded (see EXPERIMENTS.md).
  caches     : batch on ``data`` when divisible, else sequence; kv-heads on
               ``model`` when divisible, else head_dim, else sequence.

Every spec passes through ``_pick`` which only shards divisible dims —
this jax version rejects uneven input shardings.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeSpec

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes))


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh):
    """The replica/batch mesh axes: ("pod","data") when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _pick(mesh: Mesh, shape: Tuple[int, ...],
          prefs: Dict[Any, List[int]]) -> P:
    """Build a PartitionSpec: for each mesh axis (or axis tuple), assign the
    first preferred dim that is divisible by the axis size and not already
    taken.  Undividable/unclaimed dims stay replicated."""
    assignment: Dict[int, Any] = {}
    for axis, dims in prefs.items():
        size = axis_size(mesh, axis)
        if size <= 1:
            continue
        for d in dims:
            if d in assignment or d >= len(shape):
                continue
            if shape[d] % size == 0 and shape[d] > 0:
                assignment[d] = axis
                break
    spec = []
    for d in range(len(shape)):
        a = assignment.get(d)
        if isinstance(a, (tuple, list)):  # unwrap singleton axis tuples so
            a = a[0] if len(a) == 1 else tuple(a)  # specs compare equal on
        spec.append(a)                    # JAX versions without normalization
    return P(*spec)


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

_PARAM_RULES: List[Tuple[str, Dict[str, List[int]]]] = [
    # pattern (on the /-joined tree path), prefs by logical axis name.
    # dims are counted FROM THE RIGHT (negative) to be stack-agnostic:
    # a rule for (d, f) applies equally to layer-stacked (L, d, f).
    (r"embed/embedding$", {"model": [-2], "fsdp": [-1]}),
    (r"embed/unembed$", {"model": [-1], "fsdp": [-2]}),
    (r"(attn|self_attn|cross_attn)/w[qkv]$", {"model": [-1], "fsdp": [-2]}),
    (r"(attn|self_attn|cross_attn)/wqkv$", {"model": [-1], "fsdp": [-2]}),
    (r"(attn|self_attn|cross_attn)/bqkv$", {"model": [-1]}),
    (r"mlp/w_gateup$", {"model": [-1], "fsdp": [-2]}),
    (r"moe/w_gateup$", {"model": [-1], "fsdp": [-2]}),
    (r"(attn|self_attn|cross_attn)/b[qkv]$", {"model": [-1]}),
    (r"(attn|self_attn|cross_attn)/wo$", {"model": [-2], "fsdp": [-1]}),
    (r"mlp/w_(gate|up)$", {"model": [-1], "fsdp": [-2]}),
    (r"mlp/w_down$", {"model": [-2], "fsdp": [-1]}),
    (r"moe/router$", {"fsdp": [-2]}),
    (r"moe/w_(gate|up)$", {"model": [-1], "fsdp": [-2]}),
    (r"moe/w_down$", {"model": [-2], "fsdp": [-1]}),
    (r"in_proj$", {"model": [-1], "fsdp": [-2]}),
    (r"out_proj$", {"model": [-2], "fsdp": [-1]}),
    (r"conv_w$", {"model": [-1]}),
    (r"conv_b$", {"model": [-1]}),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def param_specs(mesh: Mesh, params_shape, *, fsdp: bool = True,
                expert_parallel: bool = False):
    """PartitionSpec tree for a params pytree (of ShapeDtypeStruct or
    arrays).  ``fsdp=False`` replicates the row dimension (pure-TP serving
    for small models — a §Perf hillclimb knob).  FSDP rows span
    ("pod","data") so the multi-pod mesh halves per-chip weight/optimizer
    state (grok-314b train fits 512 chips, see EXPERIMENTS.md §Dry-run)."""
    fsdp_axis = batch_axes(mesh) if fsdp else None
    rules = list(_PARAM_RULES)
    if expert_parallel:
        # expert weights (L, E, d, f): E on the model axis -> per-expert
        # GEMMs are expert-local and the dispatch becomes an all-to-all
        # instead of gathering the whole (E, tokens, d) operand (§Perf)
        rules = [(r"moe/w_(gate|up|gateup)$", {"model": [-3], "fsdp": [-2]}),
                 (r"moe/w_down$", {"model": [-3], "fsdp": [-1]})] + rules

    def spec_for(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        for pat, prefs in rules:
            if re.search(pat, pstr):
                axis_prefs: Dict[Any, List[int]] = {}
                for logical, dims in prefs.items():
                    axis = {"model": "model", "fsdp": fsdp_axis}[logical]
                    if axis is None:
                        continue
                    axis_prefs[axis] = [d % len(shape) for d in dims
                                        if -d <= len(shape)]
                return _pick(mesh, shape, axis_prefs)
        return P()  # norms, scalars, biases: replicate

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# ---------------------------------------------------------------------------
# batch / cache sharding rules
# ---------------------------------------------------------------------------

def batch_specs(mesh: Mesh, batch_shape, *,
                replicate_batch: bool = False) -> Any:
    """tokens/labels (B, L) and embeddings (B, T, d): batch on the replica
    axes (falls back to replicated when B is not divisible, e.g. B=1).
    ``replicate_batch`` replicates everything — the 2D-TP serving mode
    (EXPERIMENTS.md §Perf: decode trades FSDP weight gathers for small
    activation psums)."""
    baxes = batch_axes(mesh)

    def spec_for(path, leaf):
        if replicate_batch:
            return P(*([None] * len(leaf.shape)))
        shape = tuple(leaf.shape)
        return _pick(mesh, shape, {baxes: [0]})

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_specs(mesh: Mesh, cache_shape, *,
                replicate_batch: bool = False) -> Any:
    """Caches are (layers, B, ...) trees:
       attention k/v  (L, B, S, Hkv, hd) : B->data, Hkv|hd|S->model
       ssm conv       (L, B, k-1, ch)    : B->data, ch->model
       ssm state      (L, B, H, P, N)    : B->data, H|P->model
       cross k/v      (L, B, T, Hkv, hd) : same as attention.
    ``replicate_batch`` (2D-TP serving) moves the data axis from the batch
    dim to the SEQUENCE dim of attention caches (flash-decode-style
    sequence parallelism) and to state dims for SSM."""
    baxes = batch_axes(mesh)

    def spec_for(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        if pstr.endswith("len"):
            return P()
        if "conv" in pstr:
            prefs = {"model": [3]} if replicate_batch else                 {baxes: [1], "model": [3]}
            return _pick(mesh, shape, prefs)
        if "ssd" in pstr:
            prefs = {baxes: [2], "model": [3]} if replicate_batch else                 {baxes: [1], "model": [2, 3]}
            return _pick(mesh, shape, prefs)
        if shape and len(shape) == 5:      # attention caches
            prefs = {baxes: [2], "model": [3, 4]} if replicate_batch                 else {baxes: [1, 2], "model": [3, 4, 2]}
            return _pick(mesh, shape, prefs)
        return _pick(mesh, shape, {} if replicate_batch else {baxes: [1]})

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def opt_state_specs(mesh: Mesh, opt_shape, params_spec) -> Any:
    """Optimizer state: moments follow the param sharding; scalars
    replicate; adafactor factored moments inherit the surviving dims."""

    def spec_for(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        # find the param this moment mirrors by suffix match
        flat_params = jax.tree_util.tree_flatten_with_path(params_spec)[0]
        for ppath, pspec in flat_params:
            ps = _path_str(ppath)
            if pstr.endswith(ps) or ps.endswith(pstr.split("/", 1)[-1]):
                if len(pspec) == len(shape):
                    # verify divisibility still holds
                    ok = all(s % axis_size(mesh, a) == 0
                             for s, a in zip(shape, tuple(pspec) +
                                             (None,) * len(shape))
                             if a is not None)
                    if ok:
                        return pspec
                break
        # fallback: re-derive by heuristics (shard biggest divisible dims)
        return _pick(mesh, shape, {"model": [len(shape) - 1],
                                   "data": [len(shape) - 2]})

    return jax.tree_util.tree_map_with_path(spec_for, opt_shape)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation constraint hook (keeps remat-scan carries sharded) — the hook
# itself lives in repro.models.sharding so models never import launch/.
# ---------------------------------------------------------------------------
from repro.models import sharding as _model_sharding  # noqa: E402


def set_activation_mesh(mesh: Optional[Mesh], *,
                        shard_model: bool = True) -> None:
    """``shard_model=False`` constrains only the batch dim: d_model-sharded
    carries save remat memory for 100B+ models but cost an extra
    all-gather/reduce pair per block for small ones (EXPERIMENTS §Perf)."""
    if mesh is None:
        _model_sharding.set_activation_fn(None)
        return

    baxes = batch_axes(mesh)

    def constrain(x):
        shape = tuple(x.shape)
        prefs = {baxes: [0]}
        if shard_model:
            prefs["model"] = [len(shape) - 1]
        spec = _pick(mesh, shape, prefs)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    _model_sharding.set_activation_fn(constrain)
