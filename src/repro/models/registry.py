"""Model family registry: maps ``ModelConfig.family`` to the functional
model API, and arch ids to configs (populated by ``repro.configs``)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from . import encdec, hybrid, moe, ssm, transformer, vlm
from .config import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    forward_hidden: Callable
    logits_fn: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    # chunked (piggybacked) prefill: append a right-padded token chunk to
    # an existing cache — one trace per chunk bucket, not per prompt length
    prefill_chunk: Callable
    # paged-NATIVE entry points (attention families): the cache's sequence
    # leaves are the serving arena's page pools read through a block
    # table; attention streams K/V in place and writes only the new rows
    # back, so the fused step never materializes a dense view.  ``None``
    # for pure-SSM families (their cache is all per-slot state — the
    # state side-channel path is already gather-free).
    decode_step_paged: Optional[Callable] = None
    prefill_chunk_paged: Optional[Callable] = None
    # speculative-decoding verify: score T = k+1 fed tokens against the
    # paged cache in one fused launch, returning logits for ALL T
    # positions (B, T, V) with per-slot (B,) chunk lengths (0 = row not
    # speculating).  ``None`` for families without a paged-native chunk
    # body — the serving engine's speculation gate.
    verify_step_paged: Optional[Callable] = None


_FAMILIES: Dict[str, ModelApi] = {
    "dense": ModelApi(transformer.init, transformer.forward_hidden,
                      transformer.logits_fn, transformer.init_cache,
                      transformer.prefill, transformer.decode_step,
                      transformer.prefill_chunk,
                      transformer.decode_step_paged,
                      transformer.prefill_chunk_paged,
                      transformer.verify_step_paged),
    "moe": ModelApi(moe.init, moe.forward_hidden, moe.logits_fn,
                    moe.init_cache, moe.prefill, moe.decode_step,
                    moe.prefill_chunk, moe.decode_step_paged,
                    moe.prefill_chunk_paged, moe.verify_step_paged),
    "ssm": ModelApi(ssm.init, ssm.forward_hidden, ssm.logits_fn,
                    ssm.init_cache, ssm.prefill, ssm.decode_step,
                    ssm.prefill_chunk),
    "hybrid": ModelApi(hybrid.init, hybrid.forward_hidden, hybrid.logits_fn,
                       hybrid.init_cache, hybrid.prefill, hybrid.decode_step,
                       hybrid.prefill_chunk, hybrid.decode_step_paged,
                       hybrid.prefill_chunk_paged),
    "audio": ModelApi(encdec.init, encdec.forward_hidden, encdec.logits_fn,
                      encdec.init_cache, encdec.prefill, encdec.decode_step,
                      encdec.prefill_chunk, encdec.decode_step_paged,
                      encdec.prefill_chunk_paged),
    "vlm": ModelApi(vlm.init, vlm.forward_hidden, vlm.logits_fn,
                    vlm.init_cache, vlm.prefill, vlm.decode_step,
                    vlm.prefill_chunk, vlm.decode_step_paged,
                    vlm.prefill_chunk_paged),
}


def family_api(family: str) -> ModelApi:
    return _FAMILIES[family]


def model_api(cfg: ModelConfig) -> ModelApi:
    return family_api(cfg.family)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                *, batch_override: Optional[int] = None,
                seq_override: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of (cfg, shape) —
    weak-type-correct, shardable, no device allocation.  Used by the
    dry-run; smoke tests materialize real arrays of the same shapes."""
    import jax

    B = batch_override or shape.global_batch
    L = seq_override or shape.seq_len
    tok = jnp.int32
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, L), tok)
        specs["labels"] = jax.ShapeDtypeStruct((B, L), tok)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, L), tok)
    else:  # decode: one new token + a cache of length L (built separately)
        specs["token"] = jax.ShapeDtypeStruct((B,), tok)
    if cfg.family == "audio":
        if shape.kind == "decode":
            pass  # encoder memory lives in the cache (cross_k/v)
        else:
            specs["embeddings"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_len, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["embeddings"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_len, cfg.d_model), cfg.compute_dtype)
        # text tokens shrink so prefix + text == the assigned seq_len
        specs["tokens"] = jax.ShapeDtypeStruct((B, L - cfg.prefix_len), tok)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, L - cfg.prefix_len), tok)
    return specs
