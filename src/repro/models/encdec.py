"""Whisper-large-v3 TRANSFORMER BACKBONE (encoder-decoder).

Per the assignment carve-out, the modality frontend (mel-spectrogram +
conv feature extractor) is a STUB: ``input_specs`` feeds precomputed frame
embeddings (B, encoder_len, d_model).  This module implements the
language/decoder transformer that consumes them: a non-causal encoder
stack and a causal decoder with self- + cross-attention.

Divergence note (DESIGN.md §4): whisper's learned absolute positions are
replaced by parameter-free sinusoidal positions so the backbone lowers at
the assigned 32k/500k decode shapes (the real model caps at 448 positions).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.quant import tree_index_layer, tree_update_layer
from . import layers, transformer
from .config import ModelConfig
from .sharding import constrain_activation


def init_encoder_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "ln1": layers.init_norm(ks[0], cfg),
        "attn": layers.init_attention(ks[1], cfg),
        "ln2": layers.init_norm(ks[2], cfg),
        "mlp": layers.init_mlp(ks[3], cfg),
    }


def init_decoder_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    return {
        "ln1": layers.init_norm(ks[0], cfg),
        "self_attn": layers.init_attention(ks[1], cfg),
        "ln_x": layers.init_norm(ks[2], cfg),
        "cross_attn": layers.init_attention(ks[3], cfg, cross=True),
        "ln2": layers.init_norm(ks[4], cfg),
        "mlp": layers.init_mlp(ks[5], cfg),
    }


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    return {
        "embed": layers.init_embedding(ks[0], cfg),
        "enc_blocks": transformer.stack_layer_params(
            ks[1], cfg.encoder_layers, lambda k: init_encoder_block(k, cfg)),
        "ln_enc": layers.init_norm(ks[2], cfg),
        "dec_blocks": transformer.stack_layer_params(
            ks[3], cfg.num_layers, lambda k: init_decoder_block(k, cfg)),
        "ln_f": layers.init_norm(ks[4], cfg),
    }


def encode(params, cfg: ModelConfig, frame_embeddings, *, impl=None):
    """frame_embeddings: (B, T, d) stub frontend output -> encoder memory."""
    B, T, d = frame_embeddings.shape
    h = frame_embeddings.astype(cfg.compute_dtype)
    h = h + layers.sinusoidal_positions(T, d)[None].astype(h.dtype)

    def body(carry, lp):
        carry = constrain_activation(carry)
        a, _ = layers.attention(lp["attn"], cfg,
                                layers.apply_norm(lp["ln1"], cfg, carry),
                                causal=False, use_rope=False, impl=impl)
        x = carry + a
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        return x, None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return layers.apply_norm(params["ln_enc"], cfg, h)


def _decoder_tokens(params, cfg: ModelConfig, tokens, offset: int = 0):
    B, L = tokens.shape
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    pos = layers.sinusoidal_positions(offset + L, cfg.d_model)[offset:]
    return h + pos[None].astype(h.dtype)


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                   train: bool = False, impl=None):
    """Teacher-forced decoder over ``tokens`` given stub frame embeddings."""
    memory = encode(params, cfg, batch["embeddings"], impl=impl)
    h = _decoder_tokens(params, cfg, batch["tokens"])
    window = cfg.sliding_window

    def body(carry, lp):
        carry = constrain_activation(carry)
        a, _ = layers.attention(lp["self_attn"], cfg,
                                layers.apply_norm(lp["ln1"], cfg, carry),
                                causal=True, window=window, use_rope=False,
                                impl=impl)
        x = carry + a
        c, _ = layers.attention(lp["cross_attn"], cfg,
                                layers.apply_norm(lp["ln_x"], cfg, x),
                                kv_x=memory, causal=False, use_rope=False,
                                impl=impl)
        x = x + c
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        return x, None

    scan_body = jax.checkpoint(body) if train else body
    h, _ = jax.lax.scan(scan_body, h, params["dec_blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h)
    return h, jnp.zeros((), jnp.float32)


def logits_fn(params, cfg: ModelConfig, hidden):
    return layers.unembed(params["embed"], cfg, hidden)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    window = cfg.sliding_window
    S = min(max_len, window) if window is not None else max_len
    kv = (cfg.num_layers, batch_size, S, cfg.num_kv_heads, cfg.head_dim)
    xkv = (cfg.num_layers, batch_size, cfg.encoder_len, cfg.num_kv_heads,
           cfg.head_dim)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "cross_k": jnp.zeros(xkv, dtype), "cross_v": jnp.zeros(xkv, dtype),
            "len": jnp.zeros((), jnp.int32)}


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            cache_size: Optional[int] = None, impl=None):
    memory = encode(params, cfg, batch["embeddings"], impl=impl)
    tokens = batch["tokens"]
    B, L = tokens.shape
    window = cfg.sliding_window
    cache_size = cache_size or L
    if window is not None:
        cache_size = min(cache_size, window)
    else:
        cache_size = max(cache_size, L)  # full attention never trims
    h = _decoder_tokens(params, cfg, tokens)

    def body(carry, lp):
        carry = constrain_activation(carry)
        xn = layers.apply_norm(lp["ln1"], cfg, carry)
        a, (k, v) = layers.attention(lp["self_attn"], cfg, xn, causal=True,
                                     window=window, use_rope=False, impl=impl)
        x = carry + a
        xn = layers.apply_norm(lp["ln_x"], cfg, x)
        c, (ck, cv) = layers.attention(lp["cross_attn"], cfg, xn, kv_x=memory,
                                       causal=False, use_rope=False, impl=impl)
        x = x + c
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        if cache_size > L:
            pad = ((0, 0), (0, cache_size - L), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        elif cache_size < L:
            k, v = k[:, L - cache_size:], v[:, L - cache_size:]
            shift = L % cache_size
            k, v = jnp.roll(k, shift, axis=1), jnp.roll(v, shift, axis=1)
        return x, (k, v, ck, cv)

    h, (k, v, ck, cv) = jax.lax.scan(body, h, params["dec_blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h[:, -1:])
    logits = logits_fn(params, cfg, h[:, 0])
    cache = {"k": k, "v": v, "cross_k": ck, "cross_v": cv,
             "len": jnp.asarray(L, jnp.int32)}
    return logits, cache


def prefill_chunk(params, cfg: ModelConfig, batch, cache, *, chunk_len,
                  impl=None):
    """Chunked decoder prefill.  The FIRST chunk carries
    ``batch["embeddings"]``: it runs the encoder once and projects the
    cross-attention K/V into the cache's ``cross_k``/``cross_v`` rows;
    later chunks reuse them (the encoder never re-runs).  Decoder self-
    attention appends the chunk like ``transformer.prefill_chunk`` (no
    rope — sinusoidal positions ride on the embeddings at the chunk's
    absolute offset)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    window = cfg.sliding_window
    start = cache["len"]
    startv = jnp.asarray(start, jnp.int32).reshape(-1) * jnp.ones(
        (B,), jnp.int32)
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    pos = (startv[:, None] + jnp.arange(T)[None]).reshape(-1)
    h = h + layers.sinusoid_at(pos, cfg.d_model).reshape(
        B, T, cfg.d_model).astype(h.dtype)
    first = "embeddings" in batch
    memory = (encode(params, cfg, batch["embeddings"], impl=impl)
              if first else None)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i, ck, cv = xs
        x = constrain_activation(x)
        if first:                   # project this layer's cross K/V once
            Lk = memory.shape[1]
            ck = layers.linear(memory, lp["cross_attn"]["wk"],
                               lp["cross_attn"].get("bk")).reshape(
                B, Lk, cfg.num_kv_heads, cfg.head_dim).astype(ck.dtype)
            cv = layers.linear(memory, lp["cross_attn"]["wv"],
                               lp["cross_attn"].get("bv")).reshape(
                B, Lk, cfg.num_kv_heads, cfg.head_dim).astype(cv.dtype)
        kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        a, kc, vc = layers.attention_chunk(lp["self_attn"], cfg, xn, kc, vc,
                                           startv, chunk_len, window=window,
                                           use_rope=False, impl=impl)
        x = x + a
        xn = layers.apply_norm(lp["ln_x"], cfg, x)
        q = layers.linear(xn, lp["cross_attn"]["wq"],
                          lp["cross_attn"].get("bq")).reshape(
            B, T, cfg.num_heads, cfg.head_dim)
        c = ops.flash_attention(q, ck, cv, causal=False, impl=impl)
        c = layers.linear(c.reshape(B, T, -1), lp["cross_attn"]["wo"])
        x = x + c
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
        return (x, k_all, v_all), (ck, cv)

    (h, k, v), (ck_all, cv_all) = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["dec_blocks"], jnp.arange(cfg.num_layers),
         cache["cross_k"], cache["cross_v"]))
    h = layers.take_chunk_last(h, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "cross_k": ck_all, "cross_v": cv_all,
                    "len": cache["len"] + chunk_len}


def prefill_chunk_paged(params, cfg: ModelConfig, batch, cache,
                        block_tables, *, chunk_len, block_size, impl=None):
    """Paged-native chunked decoder prefill (see ``prefill_chunk``): the
    decoder self-attention K/V rows scatter straight into the arena page
    pools; the cross-attention K/V stay per-slot STATE (fixed
    ``encoder_len`` — the arena never pages them) and are projected once
    by the first chunk exactly as in the dense path."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    window = cfg.sliding_window
    start = jnp.asarray(cache["len"], jnp.int32).reshape(-1)
    startv = start * jnp.ones((B,), jnp.int32)
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    pos = (startv[:, None] + jnp.arange(T)[None]).reshape(-1)
    h = h + layers.sinusoid_at(pos, cfg.d_model).reshape(
        B, T, cfg.d_model).astype(h.dtype)
    first = "embeddings" in batch
    memory = (encode(params, cfg, batch["embeddings"], impl=impl)
              if first else None)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i, ck, cv = xs
        x = constrain_activation(x)
        if first:                   # project this layer's cross K/V once
            Lk = memory.shape[1]
            ck = layers.linear(memory, lp["cross_attn"]["wk"],
                               lp["cross_attn"].get("bk")).reshape(
                B, Lk, cfg.num_kv_heads, cfg.head_dim).astype(ck.dtype)
            cv = layers.linear(memory, lp["cross_attn"]["wv"],
                               lp["cross_attn"].get("bv")).reshape(
                B, Lk, cfg.num_kv_heads, cfg.head_dim).astype(cv.dtype)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        a, kp, vp = layers.attention_chunk_paged(
            lp["self_attn"], cfg, xn, kp, vp, block_tables, startv,
            chunk_len, block_size=block_size, window=window,
            use_rope=False, impl=impl)
        x = x + a
        xn = layers.apply_norm(lp["ln_x"], cfg, x)
        q = layers.linear(xn, lp["cross_attn"]["wq"],
                          lp["cross_attn"].get("bq")).reshape(
            B, T, cfg.num_heads, cfg.head_dim)
        c = ops.flash_attention(q, ck, cv, causal=False, impl=impl)
        c = layers.linear(c.reshape(B, T, -1), lp["cross_attn"]["wo"])
        x = x + c
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), (ck, cv)

    (h, k, v), (ck_all, cv_all) = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["dec_blocks"], jnp.arange(cfg.num_layers),
         cache["cross_k"], cache["cross_v"]))
    h = layers.take_chunk_last(h, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "cross_k": ck_all, "cross_v": cv_all,
                    "len": start + chunk_len}


def decode_step(params, cfg: ModelConfig, token, cache, impl=None):
    B = token.shape[0]
    window = cfg.sliding_window
    new_len = cache["len"] + 1
    x = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)
    # decode position = new_len - 1, evaluated per slot: a shared scalar
    # ``len`` broadcasts over B, a per-slot (B,) vector (the slot engine /
    # paged arena case) gives every slot its own position row
    pos = jnp.asarray(new_len - 1, jnp.float32)
    if pos.ndim == 0:
        pos = jnp.full((B,), pos)
    x = x + layers.sinusoid_at(pos, cfg.d_model).astype(x.dtype)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i, ck, cv = xs
        x = constrain_activation(x)
        kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        S = kc.shape[1]
        eff_window = None if (window is None or S <= window) else window
        xn = layers.apply_norm(lp["ln1"], cfg, x[:, None])[:, 0]
        a, kc, vc = layers.attention_decode(lp["self_attn"], cfg, xn, kc, vc,
                                            new_len, window=eff_window,
                                            use_rope=False, impl=impl)
        x = x + a
        xn = layers.apply_norm(lp["ln_x"], cfg, x[:, None])[:, 0]
        q = layers.linear(xn, lp["cross_attn"]["wq"]).reshape(
            B, cfg.num_heads, cfg.head_dim)
        c = ops.decode_attention(q, ck, cv, ck.shape[1], impl=impl)
        c = layers.linear(c.reshape(B, -1), lp["cross_attn"]["wo"])
        x = x + c
        xn = layers.apply_norm(lp["ln2"], cfg, x[:, None])[:, 0]
        x = x + layers.mlp(lp["mlp"], cfg, xn)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["dec_blocks"], jnp.arange(cfg.num_layers),
         cache["cross_k"], cache["cross_v"]))
    h = layers.apply_norm(params["ln_f"], cfg, x[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "len": new_len}


def decode_step_paged(params, cfg: ModelConfig, token, cache, block_tables,
                      live, *, block_size, impl=None):
    """Paged-native fused decode: decoder self-attention streams K/V
    through the block table and writes one row per live slot; the fixed
    encoder cross-K/V ride along as per-slot state exactly as in
    ``decode_step``."""
    B = token.shape[0]
    lens = jnp.asarray(cache["len"], jnp.int32)
    live = jnp.asarray(live, bool)
    x = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)
    # decode position = lens (per-slot), matching decode_step's new_len - 1
    x = x + layers.sinusoid_at(lens.astype(jnp.float32),
                               cfg.d_model).astype(x.dtype)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i, ck, cv = xs
        x = constrain_activation(x)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x[:, None])[:, 0]
        a, kp, vp = layers.attention_decode_paged(
            lp["self_attn"], cfg, xn, kp, vp, block_tables, lens, live,
            block_size=block_size, window=cfg.sliding_window,
            use_rope=False, impl=impl)
        x = x + a
        xn = layers.apply_norm(lp["ln_x"], cfg, x[:, None])[:, 0]
        q = layers.linear(xn, lp["cross_attn"]["wq"]).reshape(
            B, cfg.num_heads, cfg.head_dim)
        c = ops.decode_attention(q, ck, cv, ck.shape[1], impl=impl)
        c = layers.linear(c.reshape(B, -1), lp["cross_attn"]["wo"])
        x = x + c
        xn = layers.apply_norm(lp["ln2"], cfg, x[:, None])[:, 0]
        x = x + layers.mlp(lp["mlp"], cfg, xn)
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["dec_blocks"], jnp.arange(cfg.num_layers),
         cache["cross_k"], cache["cross_v"]))
    h = layers.apply_norm(params["ln_f"], cfg, x[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"],
                    "len": jnp.where(live, lens + 1, lens)}
