"""Shared neural-net layers for the model zoo.

Pure-functional: params are nested dicts of jnp arrays; every forward takes
(params, cfg, ...).  Attention flows through ``repro.kernels.ops`` so the
same model code runs the jnp reference (XLA / dry-run) or the Pallas TPU
kernels.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.quant import QuantPages, quantize
from .config import ModelConfig


# ---------------------------------------------------------------------------
# initializers / primitives
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, w, b, eps: float):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def init_norm(key, cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), cfg.weight_dtype),
                "b": jnp.zeros((d,), cfg.weight_dtype)}
    return {"w": jnp.ones((d,), cfg.weight_dtype)}


def apply_norm(p, cfg: ModelConfig, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.rms_eps)
    return rms_norm(x, p["w"], cfg.rms_eps)


def linear(x, w, b=None):
    y = jnp.einsum("...i,io->...o", x, w)
    if b is not None:
        y = y + b
    return y


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., L, H, D) rotated by ``positions`` (broadcastable to (..., L))."""
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, half)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    """Whisper-style sinusoidal positional embedding table (length, d)."""
    return sinusoid_at(jnp.arange(length), d)


def sinusoid_at(pos, d: int):
    """Sinusoidal embedding at arbitrary (possibly per-slot) positions:
    pos (B,) -> (B, d).  The decode path uses this with each slot's own
    ``len`` so requests at different depths share one fused step."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.asarray(pos, jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, *, d_in: Optional[int] = None,
                   cross: bool = False):
    d = d_in or cfg.d_model
    hd, nq, nkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = cfg.weight_dtype
    ks = jax.random.split(key, 4)
    if cfg.fused_projections and not cross:
        p = {
            "wqkv": dense_init(ks[0], (d, (nq + 2 * nkv) * hd), dt),
            "wo": dense_init(ks[3], (nq * hd, cfg.d_model), dt),
        }
        if cfg.qkv_bias:
            p["bqkv"] = jnp.zeros(((nq + 2 * nkv) * hd,), dt)
        return p
    p = {
        "wq": dense_init(ks[0], (d, nq * hd), dt),
        "wk": dense_init(ks[1], (cfg.d_model if cross else d, nkv * hd), dt),
        "wv": dense_init(ks[2], (cfg.d_model if cross else d, nkv * hd), dt),
        "wo": dense_init(ks[3], (nq * hd, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def _split_qkv_flat(cfg: ModelConfig, qkv):
    hd, nq, nkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = qkv[..., :nq * hd]
    k = qkv[..., nq * hd:(nq + nkv) * hd]
    v = qkv[..., (nq + nkv) * hd:]
    return q, k, v


def _project_qkv(p, cfg: ModelConfig, x, kv_x=None):
    B = x.shape[0]
    Lq = x.shape[1]
    kv_x = x if kv_x is None else kv_x
    Lk = kv_x.shape[1]
    if "wqkv" in p:
        q, k, v = _split_qkv_flat(cfg, linear(x, p["wqkv"], p.get("bqkv")))
    else:
        q = linear(x, p["wq"], p.get("bq"))
        k = linear(kv_x, p["wk"], p.get("bk"))
        v = linear(kv_x, p["wv"], p.get("bv"))
    q = q.reshape(B, Lq, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, Lk, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, Lk, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def attention(p, cfg: ModelConfig, x, *, positions=None, causal=True,
              window=None, prefix_len=0, kv_x=None, use_rope=True,
              impl=None):
    """Full (prefill/train) attention.  Returns (out, (k, v)) so callers can
    seed a KV cache; ``kv_x`` switches to cross-attention (no mask/rope on kv
    unless self)."""
    B, Lq, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if use_rope:
        if positions is None:
            positions = jnp.arange(Lq)[None]
        q = rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = rope(k, positions, cfg.rope_theta)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              prefix_len=prefix_len, impl=impl)
    out = out.reshape(B, Lq, cfg.num_heads * cfg.head_dim)
    return linear(out, p["wo"]), (k, v)


def attention_decode(p, cfg: ModelConfig, x_t, k_cache, v_cache, cache_len, *,
                     position=None, window=None, use_rope=True, impl=None):
    """One-token decode: x_t (B, d) vs caches (B, S, Hkv, hd).

    ``cache_len`` counts valid entries *including* the token being written
    at ring slot ``(cache_len-1) % S``.  Returns (out (B, d), k_t, v_t) —
    cache insertion is the caller's (serving.kvcache) job, so this function
    stays functional.
    """
    B = x_t.shape[0]
    if "wqkv" in p:
        q, k_t, v_t = _split_qkv_flat(
            cfg, linear(x_t, p["wqkv"], p.get("bqkv")))
    else:
        q = linear(x_t, p["wq"], p.get("bq"))
        k_t = linear(x_t, p["wk"], p.get("bk"))
        v_t = linear(x_t, p["wv"], p.get("bv"))
    q = q.reshape(B, cfg.num_heads, cfg.head_dim)
    k_t = k_t.reshape(B, cfg.num_kv_heads, cfg.head_dim)
    v_t = v_t.reshape(B, cfg.num_kv_heads, cfg.head_dim)
    if use_rope:
        pos = (cache_len - 1) if position is None else position
        pos = jnp.asarray(pos)
        if pos.ndim == 0:
            pos = jnp.full((B,), pos)
        q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k_t = rope(k_t[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    S = k_cache.shape[1]
    slot = (jnp.asarray(cache_len) - 1) % S
    if slot.ndim == 0:
        slot = jnp.full((B,), slot)

    def _insert(cache, s, t):
        return jax.lax.dynamic_update_slice(cache, t[None], (s, 0, 0))

    k_cache = jax.vmap(_insert)(k_cache, slot, k_t.astype(k_cache.dtype))
    v_cache = jax.vmap(_insert)(v_cache, slot, v_t.astype(v_cache.dtype))
    eff_len = jnp.minimum(jnp.asarray(cache_len), S)
    out = ops.decode_attention(q, k_cache, v_cache, eff_len,
                               window=window, impl=impl)
    out = out.reshape(B, cfg.num_heads * cfg.head_dim)
    return linear(out, p["wo"]), k_cache, v_cache


def attention_chunk(p, cfg: ModelConfig, x, k_cache, v_cache, cache_len,
                    chunk_len, *, window=None, prefix_len=0, use_rope=True,
                    impl=None):
    """Chunked-prefill attention: append a block of T tokens to a cache
    that already holds ``cache_len`` tokens (the piggybacked-prefill path).

    x: (B, T, d) right-padded to the static bucket size T; only the first
    ``chunk_len`` rows are real.  The chunk's K/V are written at positions
    ``cache_len + i`` for i < chunk_len (padding rows target index S, which
    the scatter drops), then the chunk queries attend causally over the
    whole cache via ``ops.chunk_attention`` — so one trace serves every
    (start, chunk_len) at a given bucket size.  Returns (out (B, T, d),
    k_cache, v_cache); rows past ``chunk_len`` are garbage the caller
    discards.
    """
    B, T, _ = x.shape
    S = k_cache.shape[1]
    if window is not None and S > window:
        raise NotImplementedError(
            "chunked prefill does not support ring (sliding-window) cache "
            "layouts; the engine gates those to one-shot prefill")
    q, k_t, v_t = _project_qkv(p, cfg, x)
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    if chunk_len.ndim == 0:
        chunk_len = jnp.full((B,), chunk_len)
    positions = cache_len[:, None] + jnp.arange(T)[None]      # (B, T)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k_t = rope(k_t, positions, cfg.rope_theta)
    # scatter the chunk's K/V rows; padded rows index S and are dropped
    idx = jnp.where(jnp.arange(T)[None] < chunk_len[:, None],
                    positions, S)

    def _insert(cache, i, t):
        return cache.at[i].set(t)

    k_cache = jax.vmap(_insert)(k_cache, idx, k_t.astype(k_cache.dtype))
    v_cache = jax.vmap(_insert)(v_cache, idx, v_t.astype(v_cache.dtype))
    out = ops.chunk_attention(q, k_cache, v_cache, cache_len, chunk_len,
                              prefix_len=prefix_len, impl=impl)
    out = out.reshape(B, T, cfg.num_heads * cfg.head_dim)
    return linear(out, p["wo"]), k_cache, v_cache


def paged_insert_rows(pages, rows, block_tables, positions, valid, *,
                      block_size: int):
    """Scatter per-slot K/V rows straight into a page pool.

    pages: one layer's physical pool (P, block_size, Hkv, D) whose LAST
    page is the arena's reserved trash block; rows: (B, T, Hkv, D) new
    cache rows; positions: (B, T) absolute token positions; valid: (B, T)
    bool — invalid rows (dead slots, chunk padding) land in the trash
    page, so the scatter stays branch-free and shape-stable.  This is the
    paged-native write path: one row per produced token, never the dense
    re-scatter of the whole view.

    A ``QuantPages`` pool quantizes the fresh float rows on insert (the
    fused scale update: int8 rows land in ``values``, their per-row f32
    scales in the sibling ``scales`` pool through the same flat scatter),
    so the pool only ever holds quantized blocks."""
    if isinstance(pages, QuantPages):
        qrows, srows = quantize(rows)
        return QuantPages(
            paged_insert_rows(pages.values, qrows, block_tables, positions,
                              valid, block_size=block_size),
            paged_insert_rows(pages.scales, srows, block_tables, positions,
                              valid, block_size=block_size))
    P = pages.shape[0]
    nblk = block_tables.shape[1]
    pos = jnp.clip(positions, 0, nblk * block_size - 1)
    blk = jnp.take_along_axis(block_tables, pos // block_size, axis=1)
    flat = blk * block_size + pos % block_size
    flat = jnp.where(valid, flat, (P - 1) * block_size)
    B, T = rows.shape[:2]
    pf = pages.reshape(P * block_size, *pages.shape[2:])
    pf = pf.at[flat.reshape(-1)].set(
        rows.reshape(B * T, *rows.shape[2:]).astype(pages.dtype))
    return pf.reshape(pages.shape)


def _no_paged_ring(window, total_tokens: int) -> None:
    if window is not None and window < total_tokens:
        raise NotImplementedError(
            "paged-native attention does not support ring (sliding-window) "
            "cache layouts; the engine gates those to the dense-view path")


def attention_decode_paged(p, cfg: ModelConfig, x_t, k_pages, v_pages,
                           block_tables, lens, live, *, block_size: int,
                           window=None, use_rope=True, impl=None):
    """One-token decode against the serving arena's paged KV layout.

    x_t: (B, d); pages: one layer's pool (P, block_size, Hkv, D) read
    through ``block_tables`` (B, nblk); ``lens`` (B,) counts tokens
    already cached (the new token is written at position ``lens``).  Only
    the new K/V row is scattered back — attention reads K/V in place via
    ``ops.paged_decode_attention``, so the hot loop never materializes a
    dense view.  Numerically identical to ``attention_decode`` on the
    gathered view (same projections, rope positions and masking)."""
    B = x_t.shape[0]
    _no_paged_ring(window, block_tables.shape[1] * block_size)
    if "wqkv" in p:
        q, k_t, v_t = _split_qkv_flat(
            cfg, linear(x_t, p["wqkv"], p.get("bqkv")))
    else:
        q = linear(x_t, p["wq"], p.get("bq"))
        k_t = linear(x_t, p["wk"], p.get("bk"))
        v_t = linear(x_t, p["wv"], p.get("bv"))
    q = q.reshape(B, cfg.num_heads, cfg.head_dim)
    k_t = k_t.reshape(B, cfg.num_kv_heads, cfg.head_dim)
    v_t = v_t.reshape(B, cfg.num_kv_heads, cfg.head_dim)
    lens = jnp.asarray(lens, jnp.int32)
    if use_rope:
        q = rope(q[:, None], lens[:, None], cfg.rope_theta)[:, 0]
        k_t = rope(k_t[:, None], lens[:, None], cfg.rope_theta)[:, 0]
    ok = jnp.asarray(live, bool)[:, None]
    k_pages = paged_insert_rows(k_pages, k_t[:, None], block_tables,
                                lens[:, None], ok, block_size=block_size)
    v_pages = paged_insert_rows(v_pages, v_t[:, None], block_tables,
                                lens[:, None], ok, block_size=block_size)
    out = ops.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                     lens + 1, impl=impl)
    out = out.reshape(B, cfg.num_heads * cfg.head_dim)
    return linear(out, p["wo"]), k_pages, v_pages


def attention_chunk_paged(p, cfg: ModelConfig, x, k_pages, v_pages,
                          block_tables, cache_len, chunk_len, *,
                          block_size: int, window=None, prefix_len=0,
                          use_rope=True, impl=None, verify=False):
    """Chunked-prefill attention against the paged KV layout: append a
    right-padded T-token chunk (only the first ``chunk_len`` rows real)
    at positions ``cache_len + i`` directly into the pages, then attend
    through the block table via ``ops.paged_chunk_attention``.  The
    multi-token sibling of ``attention_decode_paged`` (and the paged
    mirror of ``attention_chunk``).

    ``verify=True`` is the speculative-decoding verify contract: the SAME
    kernel path, but ``chunk_len`` is always a per-slot (B,) vector where
    0 marks non-speculating rows (their K/V writes route to the trash
    block and their attention rows are garbage the verifier masks) — it
    routes through ``ops.paged_verify_attention`` so the contract is
    asserted once, next to the kernels."""
    B, T, _ = x.shape
    _no_paged_ring(window, block_tables.shape[1] * block_size)
    q, k_t, v_t = _project_qkv(p, cfg, x)
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    if chunk_len.ndim == 0:
        chunk_len = jnp.full((B,), chunk_len)
    positions = cache_len[:, None] + jnp.arange(T)[None]      # (B, T)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k_t = rope(k_t, positions, cfg.rope_theta)
    valid = jnp.arange(T)[None] < chunk_len[:, None]
    k_pages = paged_insert_rows(k_pages, k_t, block_tables, positions,
                                valid, block_size=block_size)
    v_pages = paged_insert_rows(v_pages, v_t, block_tables, positions,
                                valid, block_size=block_size)
    attend = ops.paged_verify_attention if verify else \
        ops.paged_chunk_attention
    out = attend(q, k_pages, v_pages, block_tables, cache_len, chunk_len,
                 prefix_len=prefix_len, impl=impl)
    out = out.reshape(B, T, cfg.num_heads * cfg.head_dim)
    return linear(out, p["wo"]), k_pages, v_pages


def cross_attention_decode(p, cfg: ModelConfig, x_t, memory, impl=None):
    """Decode-time cross attention against a fixed encoder memory."""
    B = x_t.shape[0]
    out, _ = attention(p, cfg, x_t[:, None], kv_x=memory, causal=False,
                       use_rope=False, impl=impl)
    return out[:, 0]


def take_chunk_last(x, chunk_len):
    """x: (B, T, ...) right-padded chunk activations -> the row at
    ``chunk_len - 1`` per batch (the last REAL token's hidden state, whose
    logits seed sampling when the chunk completes a prompt)."""
    B, T = x.shape[:2]
    cl = jnp.asarray(chunk_len, jnp.int32)
    if cl.ndim == 0:
        cl = jnp.full((B,), cl)
    idx = jnp.clip(cl - 1, 0, T - 1).reshape(
        (B, 1) + (1,) * (x.ndim - 2))
    return jnp.take_along_axis(x, idx, axis=1)[:, 0]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, *, d_in: Optional[int] = None,
             d_ff: Optional[int] = None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.weight_dtype
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        if cfg.fused_projections:
            return {"w_gateup": dense_init(ks[0], (d, 2 * f), dt),
                    "w_down": dense_init(ks[2], (f, cfg.d_model), dt)}
        return {"w_gate": dense_init(ks[0], (d, f), dt),
                "w_up": dense_init(ks[1], (d, f), dt),
                "w_down": dense_init(ks[2], (f, cfg.d_model), dt)}
    return {"w_up": dense_init(ks[0], (d, f), dt),
            "w_down": dense_init(ks[1], (f, cfg.d_model), dt)}


def mlp(p, cfg: ModelConfig, x):
    if "w_gateup" in p:
        gu = linear(x, p["w_gateup"])
        f = gu.shape[-1] // 2
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(gu[..., :f]) * gu[..., f:]
    elif cfg.activation == "swiglu":
        h = jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"])
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(linear(x, p["w_gate"])) * linear(x, p["w_up"])
    else:  # gelu_mlp
        h = jax.nn.gelu(linear(x, p["w_up"]))
    return linear(h, p["w_down"])


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p = {"embedding": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                 cfg.weight_dtype, scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                  cfg.weight_dtype)
    return p


def embed(p, cfg: ModelConfig, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, p["embedding"])
    return jnp.einsum("...d,dv->...v", h, p["unembed"])
