"""Dense decoder-only transformer (llama/mistral/qwen/minicpm families).

Layer-stacked parameters + ``jax.lax.scan`` over layers keep the HLO size
O(1) in depth (88-layer configs would otherwise blow up lowering time for
the 40-combo dry-run).  Supports GQA/MQA/MHA, optional sliding window
(native for mixtral-style cfgs, or the explicit long-context variant), and
prefix-LM masking (used by the VLM wrapper).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.quant import tree_index_layer, tree_update_layer

from . import layers
from .config import ModelConfig
from .sharding import constrain_activation


def stack_layer_params(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    ps = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "ln1": layers.init_norm(ks[0], cfg),
        "attn": layers.init_attention(ks[1], cfg),
        "ln2": layers.init_norm(ks[2], cfg),
        "mlp": layers.init_mlp(ks[3], cfg),
    }


def block_forward(p, cfg: ModelConfig, x, *, positions, window, prefix_len,
                  impl=None):
    x = constrain_activation(x)
    h, _ = layers.attention(p["attn"], cfg, layers.apply_norm(p["ln1"], cfg, x),
                            positions=positions, causal=True, window=window,
                            prefix_len=prefix_len, impl=impl)
    x = x + h
    x = x + layers.mlp(p["mlp"], cfg, layers.apply_norm(p["ln2"], cfg, x))
    return x


def block_prefill(p, cfg: ModelConfig, x, *, positions, window, prefix_len,
                  cache_size, impl=None):
    x = constrain_activation(x)
    xn = layers.apply_norm(p["ln1"], cfg, x)
    h, (k, v) = layers.attention(p["attn"], cfg, xn, positions=positions,
                                 causal=True, window=window,
                                 prefix_len=prefix_len, impl=impl)
    x = x + h
    x = x + layers.mlp(p["mlp"], cfg, layers.apply_norm(p["ln2"], cfg, x))
    L = k.shape[1]
    if cache_size > L:
        pad = ((0, 0), (0, cache_size - L), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    elif cache_size < L:  # ring cache (SWA): keep the trailing window,
        # laid out so position p sits at ring slot p % cache_size (decode
        # writes token at slot (len-1) % S, so layouts must agree).
        k, v = k[:, L - cache_size:], v[:, L - cache_size:]
        shift = L % cache_size
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
    return x, (k, v)


def block_prefill_chunk(p, cfg: ModelConfig, x, k_cache, v_cache, cache_len,
                        chunk_len, *, window, prefix_len=0, impl=None):
    """Chunked-prefill block: append a T-token chunk to one layer's cache
    (per-slot ``cache_len``) and attend causally over everything written so
    far.  The multi-token sibling of ``block_decode``."""
    x = constrain_activation(x)
    xn = layers.apply_norm(p["ln1"], cfg, x)
    h, k_cache, v_cache = layers.attention_chunk(
        p["attn"], cfg, xn, k_cache, v_cache, cache_len, chunk_len,
        window=window, prefix_len=prefix_len, impl=impl)
    x = x + h
    x = x + layers.mlp(p["mlp"], cfg, layers.apply_norm(p["ln2"], cfg, x))
    return x, k_cache, v_cache


def block_decode(p, cfg: ModelConfig, x_t, k_cache, v_cache, cache_len, *,
                 window, impl=None):
    x_t = constrain_activation(x_t)
    S = k_cache.shape[1]
    eff_window = None if (window is None or S <= window) else window
    xn = layers.apply_norm(p["ln1"], cfg, x_t[:, None])[:, 0]
    h, k_cache, v_cache = layers.attention_decode(
        p["attn"], cfg, xn, k_cache, v_cache, cache_len,
        window=eff_window, impl=impl)
    x_t = x_t + h
    xn = layers.apply_norm(p["ln2"], cfg, x_t[:, None])[:, 0]
    x_t = x_t + layers.mlp(p["mlp"], cfg, xn)
    return x_t, k_cache, v_cache


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "embed": layers.init_embedding(ks[0], cfg),
        "blocks": stack_layer_params(ks[1], cfg.num_layers,
                                     lambda k: init_block(k, cfg)),
        "ln_f": layers.init_norm(ks[2], cfg),
    }


def _window(cfg: ModelConfig) -> Optional[int]:
    return cfg.sliding_window


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                   train: bool = False, impl=None):
    tokens = batch["tokens"]
    B, L = tokens.shape
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(L)[None]
    window = _window(cfg)

    def body(carry, lp):
        out = block_forward(lp, cfg, carry, positions=positions,
                            window=window, prefix_len=0, impl=impl)
        return out, None

    scan_body = jax.checkpoint(body) if train else body
    h, _ = jax.lax.scan(scan_body, h, params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h)
    return h, jnp.zeros((), jnp.float32)  # (hidden, aux_loss)


def logits_fn(params, cfg: ModelConfig, hidden):
    return layers.unembed(params["embed"], cfg, hidden)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or cfg.compute_dtype
    window = _window(cfg)
    S = min(max_len, window) if window is not None else max_len
    shape = (cfg.num_layers, batch_size, S, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            cache_size: Optional[int] = None, impl=None):
    tokens = batch["tokens"]
    B, L = tokens.shape
    window = _window(cfg)
    cache_size = cache_size or L
    if window is not None:
        cache_size = min(cache_size, window)
    else:
        cache_size = max(cache_size, L)  # full attention never trims
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(L)[None]

    def body(carry, lp):
        out, kv = block_prefill(lp, cfg, carry, positions=positions,
                                window=window, prefix_len=0,
                                cache_size=cache_size, impl=impl)
        return out, kv

    h, (k, v) = jax.lax.scan(body, h, params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h[:, -1:])
    logits = logits_fn(params, cfg, h[:, 0])
    cache = {"k": k, "v": v, "len": jnp.asarray(L, jnp.int32)}
    return logits, cache


def prefill_chunk(params, cfg: ModelConfig, batch, cache, *, chunk_len,
                  impl=None):
    """Chunked (piggybacked) prefill: append a right-padded chunk of
    ``chunk_len`` <= T prompt tokens to an existing cache whose ``len``
    counts tokens already written (0 for the first chunk).

    Chaining chunks over a prompt is numerically equivalent to one-shot
    ``prefill`` — same absolute rope positions, same causal visibility —
    but every call runs at the STATIC bucket shape (B, T), so the serving
    engine compiles one trace per chunk bucket instead of one per prompt
    length.  Returns (logits at the chunk's last real token, new cache);
    ``chunk_len`` may be a traced scalar.
    """
    tokens = batch["tokens"]
    window = _window(cfg)
    x = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    start = cache["len"]

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        x, kc, vc = block_prefill_chunk(lp, cfg, x, kc, vc, start,
                                        chunk_len, window=window, impl=impl)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.take_chunk_last(x, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": cache["len"] + chunk_len}


def prefill_chunk_paged(params, cfg: ModelConfig, batch, cache,
                        block_tables, *, chunk_len, block_size, impl=None):
    """Paged-native chunked prefill: the cache's ``k``/``v`` are the
    arena's PAGE POOLS ``(layers, pages, block_size, Hkv, D)`` read
    through ``block_tables`` (B, nblk), and ``len`` is the per-slot (B,)
    start offset.  The chunk's K/V rows scatter straight into the pages
    (``layers.attention_chunk_paged``) — no dense view is ever gathered
    or re-scattered.  Numerically equivalent to ``prefill_chunk`` on the
    gathered view."""
    tokens = batch["tokens"]
    window = _window(cfg)
    x = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    start = jnp.asarray(cache["len"], jnp.int32).reshape(-1)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        # tree-aware layer indexing: QuantPages pools (int8 + scales)
        # index/update both leaves together, dense pools are unchanged
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        h, kp, vp = layers.attention_chunk_paged(
            lp["attn"], cfg, xn, kp, vp, block_tables, start, chunk_len,
            block_size=block_size, window=window, impl=impl)
        x = x + h
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.take_chunk_last(x, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": start + chunk_len}


def verify_step_paged(params, cfg: ModelConfig, batch, cache, block_tables,
                      *, chunk_len, block_size, impl=None):
    """Speculative-decoding verify: score T = k+1 fed tokens
    ``[last_emitted, d_1 .. d_k]`` against the paged cache in ONE fused
    launch and return logits for ALL T positions ``(B, T, V)`` — the same
    chunk-attention body as ``prefill_chunk_paged`` (K/V rows scatter in
    place through the block tables; ``chunk_len`` is a per-slot (B,)
    vector, 0 for non-speculating rows whose writes route to the trash
    block), but the head runs over the full chunk instead of
    ``take_chunk_last``.  ``cache['len']`` is returned UNCHANGED: the
    engine's verifier commits lengths only after acceptance, so rejected
    draft rows are garbage past ``len`` that the next round overwrites."""
    tokens = batch["tokens"]
    window = _window(cfg)
    x = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    start = jnp.asarray(cache["len"], jnp.int32).reshape(-1)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        h, kp, vp = layers.attention_chunk_paged(
            lp["attn"], cfg, xn, kp, vp, block_tables, start, chunk_len,
            block_size=block_size, window=window, impl=impl, verify=True)
        x = x + h
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.apply_norm(params["ln_f"], cfg, x)          # all T positions
    logits = logits_fn(params, cfg, h)                     # (B, T, V)
    return logits, {"k": k, "v": v, "len": start}


def decode_step(params, cfg: ModelConfig, token, cache, impl=None):
    """token: (B,) int32.  One new token; cache['len'] counts tokens already
    in the cache (the new token is written at ring slot len % S).

    The full stacked cache rides in the scan CARRY and is updated with
    dynamic_update_index — XLA performs carry DUS in place, so a donated
    cache costs ONE buffer instead of the scan xs+ys double buffer (which
    blew the 16 GB/chip budget at decode_32k — EXPERIMENTS.md §Dry-run)."""
    B = token.shape[0]
    window = _window(cfg)
    new_len = cache["len"] + 1
    x = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        out, kc, vc = block_decode(lp, cfg, x, kc, vc, new_len,
                                   window=window, impl=impl)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
        return (out, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.apply_norm(params["ln_f"], cfg, x[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": new_len}


def decode_step_paged(params, cfg: ModelConfig, token, cache, block_tables,
                      live, *, block_size, impl=None):
    """Paged-native fused decode: cache ``k``/``v`` are the arena PAGE
    POOLS ``(layers, pages, block_size, Hkv, D)``, ``len`` the per-slot
    (B,) lengths.  Attention reads K/V in place through ``block_tables``
    and writes back only each live slot's ONE new row — the O(capacity x
    slot_tokens x layers) dense materialize/re-scatter round trip of the
    gather path never happens.  ``live`` masks dead/prefilling slots:
    their row writes route to the trash page and their lengths hold."""
    B = token.shape[0]
    window = _window(cfg)
    lens = jnp.asarray(cache["len"], jnp.int32)
    live = jnp.asarray(live, bool)
    x = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x[:, None])[:, 0]
        h, kp, vp = layers.attention_decode_paged(
            lp["attn"], cfg, xn, kp, vp, block_tables, lens, live,
            block_size=block_size, window=window, impl=impl)
        x = x + h
        xn = layers.apply_norm(lp["ln2"], cfg, x[:, None])[:, 0]
        x = x + layers.mlp(lp["mlp"], cfg, xn)
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.apply_norm(params["ln_f"], cfg, x[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": jnp.where(live, lens + 1, lens)}
