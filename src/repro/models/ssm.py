"""Mamba-2 (SSD — state-space duality) model, attention-free (mamba2-2.7b).

Block = in_proj -> causal depthwise conv (silu) -> SSD chunked scan (the
Pallas ``ssd_scan`` kernel on TPU) -> gated RMSNorm -> out_proj.  Decode is
O(1) per token: a (k-1)-deep conv state plus the (H, P, N) SSD state —
this is what makes long_500k natively sub-quadratic for this family.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import layers, transformer
from .config import ModelConfig
from .sharding import constrain_activation


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------

def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_mamba_block(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    H, G, N = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state
    k = cfg.ssm_conv_kernel
    ch = conv_channels(cfg)
    dt_ = cfg.weight_dtype
    ks = jax.random.split(key, 5)
    d_proj = 2 * di + 2 * G * N + H
    return {
        "ln": layers.init_norm(ks[0], cfg),
        "in_proj": layers.dense_init(ks[1], (d, d_proj), dt_),
        "conv_w": layers.dense_init(ks[2], (k, ch), dt_, scale=k ** -0.5),
        "conv_b": jnp.zeros((ch,), dt_),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = -exp(A_log) = -1
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_ln": {"w": jnp.ones((di,), dt_)},
        "out_proj": layers.dense_init(ks[3], (di, d), dt_),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(p, u):
    """u: (B, L, ch) depthwise causal conv, kernel (k, ch)."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    L = u.shape[1]
    y = sum(pad[:, i:i + L] * p["conv_w"][i][None, None] for i in range(k))
    return jax.nn.silu((y + p["conv_b"][None, None]).astype(jnp.float32)
                       ).astype(u.dtype)


def _conv_step(p, conv_state, u_t):
    """conv_state: (B, k-1, ch); u_t: (B, ch) -> (y_t, new_state)."""
    k = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_state, u_t[:, None]], axis=1)  # (B, k, ch)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32))
    y = jax.nn.silu(y + p["conv_b"].astype(jnp.float32)).astype(u_t.dtype)
    return y, window[:, 1:]


def mamba_block(p, cfg: ModelConfig, x, *, initial_state=None,
                return_state=False, impl=None):
    """x: (B, L, d) -> (B, L, d) [+ (conv_tail, ssd_state)]."""
    x = constrain_activation(x)
    B, L, d = x.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                      cfg.ssm_nheads, cfg.ssm_headdim)
    xn = layers.apply_norm(p["ln"], cfg, x)
    zxbcdt = layers.linear(xn, p["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC_conv = _causal_conv(p, xBC)
    xs = xBC_conv[..., :di].reshape(B, L, H, P)
    Bm = xBC_conv[..., di:di + G * N].reshape(B, L, G, N)
    Cm = xBC_conv[..., di + G * N:].reshape(B, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, state = ops.ssd_scan(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk,
                            initial_state=initial_state, impl=impl)
    y = y.reshape(B, L, di)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["gate_ln"]["w"], cfg.rms_eps)
    out = layers.linear(y, p["out_proj"])
    if return_state:
        k = cfg.ssm_conv_kernel
        tail = xBC[:, -(k - 1):] if L >= k - 1 else jnp.pad(
            xBC, ((0, 0), (k - 1 - L, 0), (0, 0)))
        return x + out, (tail, state)
    return x + out


def mamba_block_chunk(p, cfg: ModelConfig, x, conv_state, ssd_state,
                      chunk_len, *, impl=None):
    """Chunked-prefill mamba block: advance one layer's recurrent state by
    a right-padded chunk of ``chunk_len`` <= T tokens.

    x: (B, T, d); conv_state: (B, k-1, ch) raw pre-conv tail; ssd_state:
    (B, H, P, N).  Padding rows past ``chunk_len`` are made IDENTITY steps
    by zeroing their dt (exp(A*0) = 1 keeps the SSD state, dt*x = 0 adds
    nothing), and the new conv tail is gathered ending at the last REAL
    token — so the returned state equals running exactly ``chunk_len``
    steps.  Returns (out (B, T, d), conv_tail, ssd_state).
    """
    x = constrain_activation(x)
    B, T, d = x.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                      cfg.ssm_nheads, cfg.ssm_headdim)
    k = cfg.ssm_conv_kernel
    xn = layers.apply_norm(p["ln"], cfg, x)
    zxbcdt = layers.linear(xn, p["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    cl = jnp.asarray(chunk_len, jnp.int32)
    if cl.ndim == 0:
        cl = jnp.full((B,), cl)
    # causal conv primed with the carried (k-1)-deep raw tail
    padded = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    y = sum(padded[:, i:i + T] * p["conv_w"][i][None, None]
            for i in range(k))
    xBC_conv = jax.nn.silu((y + p["conv_b"][None, None])
                           .astype(jnp.float32)).astype(xBC.dtype)
    xs = xBC_conv[..., :di].reshape(B, T, H, P)
    Bm = xBC_conv[..., di:di + G * N].reshape(B, T, G, N)
    Cm = xBC_conv[..., di + G * N:].reshape(B, T, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    valid = jnp.arange(T)[None] < cl[:, None]                 # (B, T)
    dt = jnp.where(valid[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"])
    y, state = ops.ssd_scan(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk,
                            initial_state=ssd_state, impl=impl)
    y = y.reshape(B, T, di)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["gate_ln"]["w"], cfg.rms_eps)
    out = x + layers.linear(y, p["out_proj"])
    # new raw tail: the k-1 positions ending at the last real token (the
    # conv_state prefix covers chunks shorter than the kernel)
    idx = cl[:, None] + jnp.arange(k - 1)[None]               # (B, k-1)
    tail = jnp.take_along_axis(padded, idx[..., None], axis=1)
    return out, tail, state


def mamba_block_decode(p, cfg: ModelConfig, x_t, conv_state, ssd_state, *,
                       impl=None):
    """x_t: (B, d); conv_state: (B, k-1, ch); ssd_state: (B, H, P, N)."""
    x_t = constrain_activation(x_t)
    B, d = x_t.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                      cfg.ssm_nheads, cfg.ssm_headdim)
    xn = layers.apply_norm(p["ln"], cfg, x_t[:, None])[:, 0]
    zxbcdt = layers.linear(xn, p["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC_conv, conv_state = _conv_step(p, conv_state, xBC)
    xs = xBC_conv[..., :di].reshape(B, H, P)
    Bm = xBC_conv[..., di:di + G * N].reshape(B, G, N)
    Cm = xBC_conv[..., di + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    y, ssd_state = ops.ssd_decode_step(ssd_state, xs, dt, A, Bm, Cm, p["D"])
    y = y.reshape(B, di)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["gate_ln"]["w"], cfg.rms_eps)
    return x_t + layers.linear(y, p["out_proj"]), conv_state, ssd_state


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "embed": layers.init_embedding(ks[0], cfg),
        "blocks": transformer.stack_layer_params(
            ks[1], cfg.num_layers, lambda k: init_mamba_block(k, cfg)),
        "ln_f": layers.init_norm(ks[2], cfg),
    }


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                   train: bool = False, impl=None):
    tokens = batch["tokens"]
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)

    def body(carry, lp):
        return mamba_block(lp, cfg, carry, impl=impl), None

    scan_body = jax.checkpoint(body) if train else body
    h, _ = jax.lax.scan(scan_body, h, params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h)
    return h, jnp.zeros((), jnp.float32)


def logits_fn(params, cfg: ModelConfig, hidden):
    return layers.unembed(params["embed"], cfg, hidden)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    k, ch = cfg.ssm_conv_kernel, conv_channels(cfg)
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    Lyr = cfg.num_layers
    return {
        "conv": jnp.zeros((Lyr, batch_size, k - 1, ch), dtype),
        "ssd": jnp.zeros((Lyr, batch_size, H, P, N), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            cache_size: Optional[int] = None, impl=None):
    tokens = batch["tokens"]
    B, L = tokens.shape
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)

    def body(carry, lp):
        out, (tail, state) = mamba_block(lp, cfg, carry, return_state=True,
                                         impl=impl)
        return out, (tail, state)

    h, (conv, ssd) = jax.lax.scan(body, h, params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h[:, -1:])
    logits = logits_fn(params, cfg, h[:, 0])
    cache = {"conv": conv, "ssd": ssd, "len": jnp.asarray(L, jnp.int32)}
    return logits, cache


def prefill_chunk(params, cfg: ModelConfig, batch, cache, *, chunk_len,
                  impl=None):
    """Chunked prefill: advance the conv/SSD state by one right-padded
    chunk (see ``mamba_block_chunk``); chaining chunks matches one-shot
    ``prefill`` because the recurrence is exact — padding steps are
    identity and the conv tail tracks the last real token."""
    tokens = batch["tokens"]
    x = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)

    def body(carry, xs):
        x, conv_all, ssd_all = carry
        lp, i = xs
        conv = jax.lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
        ssd = jax.lax.dynamic_index_in_dim(ssd_all, i, 0, keepdims=False)
        x, conv, ssd = mamba_block_chunk(lp, cfg, x, conv, ssd, chunk_len,
                                         impl=impl)
        conv_all = jax.lax.dynamic_update_index_in_dim(
            conv_all, conv.astype(conv_all.dtype), i, 0)
        ssd_all = jax.lax.dynamic_update_index_in_dim(
            ssd_all, ssd.astype(ssd_all.dtype), i, 0)
        return (x, conv_all, ssd_all), None

    (x, conv, ssd), _ = jax.lax.scan(
        body, (x, cache["conv"], cache["ssd"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.take_chunk_last(x, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"conv": conv, "ssd": ssd, "len": cache["len"] + chunk_len}


def decode_step(params, cfg: ModelConfig, token, cache, impl=None):
    """Carry-DUS cache update (see transformer.decode_step): one in-place
    state buffer instead of scan xs+ys double-buffering."""
    x = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)

    def body(carry, xs):
        x, conv_all, ssd_all = carry
        lp, i = xs
        conv = jax.lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
        ssd = jax.lax.dynamic_index_in_dim(ssd_all, i, 0, keepdims=False)
        out, conv, ssd = mamba_block_decode(lp, cfg, x, conv, ssd,
                                            impl=impl)
        conv_all = jax.lax.dynamic_update_index_in_dim(conv_all, conv, i, 0)
        ssd_all = jax.lax.dynamic_update_index_in_dim(
            ssd_all, ssd.astype(ssd_all.dtype), i, 0)
        return (out, conv_all, ssd_all), None

    (x, conv, ssd), _ = jax.lax.scan(
        body, (x, cache["conv"], cache["ssd"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.apply_norm(params["ln_f"], cfg, x[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"conv": conv, "ssd": ssd, "len": cache["len"] + 1}
