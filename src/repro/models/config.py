"""Model configuration dataclasses shared by the model zoo and configs/.

Every assigned architecture instantiates a :class:`ModelConfig`.  The config
is deliberately flat — one dataclass covers dense / MoE / SSM / hybrid /
enc-dec / VLM families, with family-specific fields defaulting to inert
values.  ``family`` selects the forward implementation in
``repro.models.registry``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identification
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    citation: str = ""

    # transformer backbone
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    qkv_bias: bool = False          # qwen1.5 style
    fused_projections: bool = False  # fused QKV + gate|up matmuls: 1 bwd
    #                                  dx all-reduce instead of 3 (resp. 2)
    #                                  under tensor parallelism (§Perf)
    tie_embeddings: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    activation: str = "swiglu"       # swiglu | geglu | gelu_mlp
    sliding_window: Optional[int] = None   # native SWA (mixtral)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # hybrid (zamba2): a shared attention block applied every `attn_every`
    # SSM layers, consuming concat(h, h0) like the Zamba family.
    attn_every: int = 0

    # enc-dec (whisper): number of encoder layers + encoder memory length.
    encoder_layers: int = 0
    encoder_len: int = 0             # 1500 audio frames for whisper

    # vlm (paligemma): number of image-prefix tokens fed as embeddings.
    prefix_len: int = 0

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # EPARA control-plane category hints (latency|frequency, gpus estimate)
    epara_sensitivity: str = "latency"
    epara_multi_gpu: bool = False

    # ------------------------------------------------------------------
    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Whether long-context decode is natively sub-quadratic-safe
        (bounded attention working set): SSMs, hybrids with windowed shared
        attention, and SWA models."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd = self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d

        def attn_params(dm):
            return dm * (nq * hd) + 2 * dm * (nkv * hd) + (nq * hd) * dm

        def mlp_params(dm, ff):
            if self.activation in ("swiglu", "geglu"):
                return 3 * dm * ff
            return 2 * dm * ff

        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn_params(d) + mlp_params(d, f) + 2 * d
            total += L * per_layer
            if self.family == "audio":
                # decoder cross-attention + encoder stack
                total += L * attn_params(d)
                enc_per = attn_params(d) + mlp_params(d, f) + 2 * d
                total += self.encoder_layers * enc_per
        elif self.family == "moe":
            per_layer = attn_params(d) + 2 * d
            per_layer += self.num_experts * mlp_params(d, f)
            per_layer += d * self.num_experts  # router
            total += L * per_layer
        elif self.family == "ssm":
            total += L * self._ssm_block_params()
        elif self.family == "hybrid":
            total += L * self._ssm_block_params()
            # one shared attention+mlp block over concat(h, h0)
            total += (2 * d) * (nq * hd) + 2 * (2 * d) * (nkv * hd) \
                + (nq * hd) * d + mlp_params(d, f)
        return total

    def _ssm_block_params(self) -> int:
        d, di, N = self.d_model, self.d_inner, self.ssm_state
        H, G, k = self.ssm_nheads, self.ssm_ngroups, self.ssm_conv_kernel
        in_proj = d * (2 * di + 2 * G * N + H)
        conv = (di + 2 * G * N) * k
        out_proj = di * d
        extras = 2 * H + di + d  # A_log, D, gate-norm, rmsnorm
        return in_proj + conv + out_proj + extras

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE counts top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        total = self.param_count()
        inactive = (self.num_experts - self.experts_per_token) * 3 * d * f * L
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

INPUT_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized variant of the same family (2 layers, d_model<=256,
    <=4 experts) used by per-arch smoke tests on CPU."""
    small = dict(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(4, cfg.num_kv_heads) or 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.family == "moe":
        small.update(num_experts=min(4, cfg.num_experts), experts_per_token=2)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
    if cfg.family == "hybrid":
        small.update(attn_every=2)
    if cfg.family == "audio":
        small.update(encoder_layers=2, encoder_len=64)
    if cfg.family == "vlm":
        small.update(prefix_len=16)
    if cfg.sliding_window is not None:
        small.update(sliding_window=64)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
