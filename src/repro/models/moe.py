"""Mixture-of-Experts decoder (mixtral-8x7b, grok-1-314b families).

GShard/Switch-style capacity-based top-k routing: tokens are grouped per
sequence, the dispatch/combine tensors are (G, S, E, C) one-hots (cheap
relative to the expert GEMMs at these widths), and the expert FFN runs
through ``ops.grouped_matmul`` — the Pallas grouped-GEMM kernel on TPU.
The attention/backbone is shared with ``transformer``; only the FFN differs.

Aux load-balance loss (Switch, eq. 4) is returned so training can weight it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.quant import tree_index_layer, tree_update_layer
from . import layers, transformer
from .config import ModelConfig
from .sharding import constrain_activation


# ---------------------------------------------------------------------------
# router + dispatch
# ---------------------------------------------------------------------------

def init_moe_mlp(key, cfg: ModelConfig):
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    dt = cfg.weight_dtype
    ks = jax.random.split(key, 4)
    return {
        "router": layers.dense_init(ks[0], (d, E), dt),
        "w_gate": layers.dense_init(ks[1], (E, d, f), dt),
        "w_up": layers.dense_init(ks[2], (E, d, f), dt),
        "w_down": layers.dense_init(ks[3], (E, f, d), dt),
    }


def _top_k_dispatch(router_probs, k: int, capacity: int):
    """router_probs: (G, S, E).  Returns combine (G, S, E, C) fp32, the
    aux load-balance loss and the number of token→expert assignments
    dropped by the capacity limit.  Capacity-dropped tokens get zero
    combine weight (residual passes them through)."""
    G, S, E = router_probs.shape
    combine = jnp.zeros((G, S, E, capacity), jnp.float32)
    probs = router_probs
    # fraction of tokens routed (first choice) per expert, for aux loss
    top1 = jnp.argmax(probs, axis=-1)
    me = jnp.mean(probs, axis=1)                         # (G, E)
    ce = jnp.mean(jax.nn.one_hot(top1, E), axis=1)        # (G, E)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * (E ** 2) / (E * 1.0)

    occupancy = jnp.zeros((G, E), jnp.int32)
    dropped = jnp.zeros((), jnp.float32)
    for _ in range(k):
        idx = jnp.argmax(probs, axis=-1)                  # (G, S)
        gate = jnp.take_along_axis(probs, idx[..., None], -1)[..., 0]
        mask = jax.nn.one_hot(idx, E, dtype=jnp.int32)    # (G, S, E)
        pos = jnp.cumsum(mask, axis=1) - mask + occupancy[:, None]
        pos = jnp.sum(pos * mask, axis=-1)                # (G, S)
        keep = pos < capacity
        dropped = dropped + jnp.sum((~keep).astype(jnp.float32))
        onehot_c = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
        contrib = (gate * keep)[..., None, None] \
            * mask[..., None].astype(jnp.float32) * onehot_c[..., None, :]
        combine = combine + contrib
        occupancy = occupancy + jnp.sum(mask, axis=1)
        probs = probs * (1.0 - mask.astype(probs.dtype))  # mask out chosen
    # renormalize the kept gates so the k gates sum to 1 (mixtral semantics)
    denom = jnp.sum(combine, axis=(-2, -1), keepdims=True)
    combine = combine / jnp.maximum(denom, 1e-9)
    return combine, aux, dropped


# ---------------------------------------------------------------------------
# expert-capacity drop counter (ROADMAP PR 3 follow-up): chunked prefill
# changes the routing-group granularity, so outputs can diverge from
# one-shot prefill exactly when the capacity limit is BINDING — i.e. when
# tokens are dropped.  The counter makes that observable: the serving
# engine enables it for MoE services and reports per-step drop deltas in
# ``StepStats.moe_dropped_tokens``.  It is a process-global accumulator
# fed by ``jax.debug.callback`` (the only host-side channel out of a
# jitted step); the flag is checked at TRACE time, so training and other
# disabled paths pay nothing.  Per-step attribution is exact in the
# single-threaded serving loop (every step blocks on its sampled tokens,
# flushing the callbacks, before the next runtime steps) but only
# approximate if several MoE runtimes ever step concurrently; counts also
# include padding/garbage rows of masked serving batches — it is an
# observability signal, not an exact per-request audit.
# ---------------------------------------------------------------------------

class _MoeDropStats:
    __slots__ = ("dropped", "assigned")

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.dropped = 0.0
        self.assigned = 0.0

    @property
    def drop_rate(self) -> float:
        return self.dropped / self.assigned if self.assigned else 0.0


MOE_DROP_STATS = _MoeDropStats()
_DROP_COUNTER_ENABLED = False


def enable_drop_counter(on: bool = True) -> None:
    """Toggle drop accounting for traces built AFTER the call (already
    compiled functions keep their behaviour)."""
    global _DROP_COUNTER_ENABLED
    _DROP_COUNTER_ENABLED = bool(on)


def _note_drops(dropped, assigned) -> None:
    MOE_DROP_STATS.dropped += float(dropped)
    MOE_DROP_STATS.assigned += float(assigned)


MAX_ROUTING_GROUP = 2048


def moe_mlp(p, cfg: ModelConfig, x, *, impl=None):
    """x: (B, L, d) -> (B, L, d), plus aux loss.

    Long sequences are split into routing groups of <= MAX_ROUTING_GROUP
    tokens (GShard-style): expert capacity — and with it the (G, S, E, C)
    dispatch tensors — scales with the group, not the sequence (a 32k
    prefill would otherwise need C~10k and TB-scale one-hots)."""
    B, L, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    seg = min(L, MAX_ROUTING_GROUP)
    pad = (-L) % seg
    xg = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    G = xg.shape[1] // seg
    xg = xg.reshape(B * G, seg, d)
    capacity = max(1, int(cfg.moe_capacity_factor * k * seg / E))
    logits = layers.linear(xg.astype(jnp.float32),
                           p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (BG, seg, E)
    combine, aux, dropped = _top_k_dispatch(probs, k, capacity)
    if _DROP_COUNTER_ENABLED:                             # trace-time gate
        jax.debug.callback(_note_drops, dropped,
                           jnp.asarray(float(k * B * G * seg), jnp.float32))
    dispatch = (combine > 0).astype(x.dtype)              # (BG, seg, E, C)
    # (BG, S, E, C) x (BG, S, d) -> (E, BG*C, d)
    expert_in = jnp.einsum("blec,bld->ebcd", dispatch, xg)
    expert_in = expert_in.reshape(E, B * G * capacity, d)
    gate = ops.grouped_matmul(expert_in, p["w_gate"], impl=impl)
    up = ops.grouped_matmul(expert_in, p["w_up"], impl=impl)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = ops.grouped_matmul(h, p["w_down"], impl=impl)
    out = out.reshape(E, B * G, capacity, d)
    y = jnp.einsum("blec,ebcd->bld", combine.astype(x.dtype), out)
    y = y.reshape(B, G * seg, d)
    return y[:, :L], aux


# ---------------------------------------------------------------------------
# blocks / model API (attention backbone shared with transformer)
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "ln1": layers.init_norm(ks[0], cfg),
        "attn": layers.init_attention(ks[1], cfg),
        "ln2": layers.init_norm(ks[2], cfg),
        "moe": init_moe_mlp(ks[3], cfg),
    }


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "embed": layers.init_embedding(ks[0], cfg),
        "blocks": transformer.stack_layer_params(
            ks[1], cfg.num_layers, lambda k: init_block(k, cfg)),
        "ln_f": layers.init_norm(ks[2], cfg),
    }


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                   train: bool = False, impl=None):
    tokens = batch["tokens"]
    B, L = tokens.shape
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(L)[None]
    window = cfg.sliding_window

    def body(carry, lp):
        x, aux = carry
        x = constrain_activation(x)
        a, _ = layers.attention(lp["attn"], cfg,
                                layers.apply_norm(lp["ln1"], cfg, x),
                                positions=positions, window=window, impl=impl)
        x = x + a
        m, aux_l = moe_mlp(lp["moe"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x), impl=impl)
        return (x + m, aux + aux_l), None

    scan_body = jax.checkpoint(body) if train else body
    (h, aux), _ = jax.lax.scan(scan_body, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h)
    return h, aux / cfg.num_layers


def logits_fn(params, cfg: ModelConfig, hidden):
    return layers.unembed(params["embed"], cfg, hidden)


init_cache = transformer.init_cache


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            cache_size: Optional[int] = None, impl=None):
    tokens = batch["tokens"]
    B, L = tokens.shape
    window = cfg.sliding_window
    cache_size = cache_size or L
    if window is not None:
        cache_size = min(cache_size, window)
    else:
        cache_size = max(cache_size, L)  # full attention never trims
    h = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(L)[None]

    def body(carry, lp):
        carry = constrain_activation(carry)
        xn = layers.apply_norm(lp["ln1"], cfg, carry)
        a, (k, v) = layers.attention(lp["attn"], cfg, xn, positions=positions,
                                     window=window, impl=impl)
        x = carry + a
        m, _ = moe_mlp(lp["moe"], cfg,
                       layers.apply_norm(lp["ln2"], cfg, x), impl=impl)
        x = x + m
        if cache_size > L:
            pad = ((0, 0), (0, cache_size - L), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        elif cache_size < L:
            k, v = k[:, L - cache_size:], v[:, L - cache_size:]
            shift = L % cache_size
            k, v = jnp.roll(k, shift, axis=1), jnp.roll(v, shift, axis=1)
        return x, (k, v)

    h, (k, v) = jax.lax.scan(body, h, params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h[:, -1:])
    logits = logits_fn(params, cfg, h[:, 0])
    return logits, {"k": k, "v": v, "len": jnp.asarray(L, jnp.int32)}


def prefill_chunk(params, cfg: ModelConfig, batch, cache, *, chunk_len,
                  impl=None):
    """Chunked prefill (see ``transformer.prefill_chunk``).  The chunk is
    its own MoE routing group: expert capacity scales with the bucket, not
    the prompt, so per-token outputs match one-shot prefill exactly
    whenever capacity is not binding (padding rows past ``chunk_len`` do
    compete for capacity at tight ``moe_capacity_factor``)."""
    tokens = batch["tokens"]
    window = cfg.sliding_window
    x = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    start = cache["len"]

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        a, kc, vc = layers.attention_chunk(lp["attn"], cfg, xn, kc, vc,
                                           start, chunk_len, window=window,
                                           impl=impl)
        x = x + a
        m, _ = moe_mlp(lp["moe"], cfg,
                       layers.apply_norm(lp["ln2"], cfg, x), impl=impl)
        x = x + m
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.take_chunk_last(x, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": cache["len"] + chunk_len}


def prefill_chunk_paged(params, cfg: ModelConfig, batch, cache,
                        block_tables, *, chunk_len, block_size, impl=None):
    """Paged-native chunked prefill (see ``transformer.prefill_chunk_paged``
    and ``prefill_chunk``'s routing-group caveat): chunk K/V rows scatter
    straight into the arena page pools, the MoE FFN is unchanged."""
    tokens = batch["tokens"]
    window = cfg.sliding_window
    x = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    start = jnp.asarray(cache["len"], jnp.int32).reshape(-1)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        a, kp, vp = layers.attention_chunk_paged(
            lp["attn"], cfg, xn, kp, vp, block_tables, start, chunk_len,
            block_size=block_size, window=window, impl=impl)
        x = x + a
        m, _ = moe_mlp(lp["moe"], cfg,
                       layers.apply_norm(lp["ln2"], cfg, x), impl=impl)
        x = x + m
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.take_chunk_last(x, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": start + chunk_len}


def verify_step_paged(params, cfg: ModelConfig, batch, cache, block_tables,
                      *, chunk_len, block_size, impl=None):
    """Speculative-decoding verify (see ``transformer.verify_step_paged``):
    the ``prefill_chunk_paged`` body with the head over ALL T positions
    instead of ``take_chunk_last`` — logits come back ``(B, T, V)`` and
    ``cache['len']`` is returned unchanged (the engine commits lengths
    after acceptance).  Expert routing stays per-chunk, matching the
    chunked-prefill granularity the drafts were verified against."""
    tokens = batch["tokens"]
    window = cfg.sliding_window
    x = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    start = jnp.asarray(cache["len"], jnp.int32).reshape(-1)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        a, kp, vp = layers.attention_chunk_paged(
            lp["attn"], cfg, xn, kp, vp, block_tables, start, chunk_len,
            block_size=block_size, window=window, impl=impl, verify=True)
        x = x + a
        m, _ = moe_mlp(lp["moe"], cfg,
                       layers.apply_norm(lp["ln2"], cfg, x), impl=impl)
        x = x + m
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.apply_norm(params["ln_f"], cfg, x)          # all T positions
    logits = logits_fn(params, cfg, h)                     # (B, T, V)
    return logits, {"k": k, "v": v, "len": start}


def _moe_mlp_single(p, cfg: ModelConfig, x_t, *, impl=None):
    """Decode-time MoE for a (B, d) token batch.

    Routes each slot's token as its OWN dispatch group (B groups of S=1)
    through the same capacity machinery as prefill — never gathers expert
    weights per token (that would stream B*k full expert FFNs from HBM),
    and the grouped matmuls still see one fused (E, B*C, d) stack.
    Per-slot grouping matters for the serving engine: a shared group would
    make tokens compete for expert capacity across requests, so a slot's
    output would depend on its batch neighbours (and, under the paged
    arena's fixed-capacity batch, on unoccupied slots' garbage rows) —
    per-token groups keep every decode row numerically independent."""
    y, _ = moe_mlp(p, cfg, x_t[:, None], impl=impl)
    return y[:, 0]


def decode_step(params, cfg: ModelConfig, token, cache, impl=None):
    B = token.shape[0]
    window = cfg.sliding_window
    new_len = cache["len"] + 1
    x = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        S = kc.shape[1]
        eff_window = None if (window is None or S <= window) else window
        xn = layers.apply_norm(lp["ln1"], cfg, x[:, None])[:, 0]
        a, kc, vc = layers.attention_decode(lp["attn"], cfg, xn, kc, vc,
                                            new_len, window=eff_window,
                                            impl=impl)
        x = x + a
        xn = layers.apply_norm(lp["ln2"], cfg, x[:, None])[:, 0]
        x = x + _moe_mlp_single(lp["moe"], cfg, xn, impl=impl)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.apply_norm(params["ln_f"], cfg, x[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": new_len}


def decode_step_paged(params, cfg: ModelConfig, token, cache, block_tables,
                      live, *, block_size, impl=None):
    """Paged-native fused decode (see ``transformer.decode_step_paged``):
    attention streams K/V through the block table, the per-token-group
    MoE FFN keeps every decode row numerically independent of its batch
    neighbours (so fixed-capacity garbage rows stay harmless)."""
    window = cfg.sliding_window
    lens = jnp.asarray(cache["len"], jnp.int32)
    live = jnp.asarray(live, bool)
    x = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x[:, None])[:, 0]
        a, kp, vp = layers.attention_decode_paged(
            lp["attn"], cfg, xn, kp, vp, block_tables, lens, live,
            block_size=block_size, window=window, impl=impl)
        x = x + a
        xn = layers.apply_norm(lp["ln2"], cfg, x[:, None])[:, 0]
        x = x + _moe_mlp_single(lp["moe"], cfg, xn, impl=impl)
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.apply_norm(params["ln_f"], cfg, x[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": jnp.where(live, lens + 1, lens)}
