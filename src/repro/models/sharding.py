"""Activation-sharding hook.

Model forwards call ``constrain_activation`` on scan carries at block
boundaries.  Outside a mesh deployment (CPU tests, examples) it is the
identity; the launcher installs a ``with_sharding_constraint`` closure so
remat-scan carries stay sharded (batch on the replica axes, d_model on
``model``) instead of ballooning to replicated (B, L, d) per layer — see
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Callable, Optional

_FN: list = [None]


def set_activation_fn(fn: Optional[Callable]) -> None:
    _FN[0] = fn


def constrain_activation(x):
    fn = _FN[0]
    return x if fn is None else fn(x)
