"""PaliGemma-3B LANGUAGE BACKBONE (gemma-2b decoder + image-prefix).

The SigLIP vision tower + projector are a STUB per the assignment
carve-out: ``input_specs`` feeds precomputed patch embeddings
(B, prefix_len, d_model).  This module implements the gemma-style decoder
(MQA kv=1, head_dim 256, geglu, tied embeddings) with PaliGemma's
prefix-LM masking: bidirectional attention over the image prefix, causal
over text.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.quant import tree_index_layer, tree_update_layer
from . import layers, transformer
from .config import ModelConfig
from .sharding import constrain_activation


init = transformer.init          # same param structure as a dense decoder
init_block = transformer.init_block
logits_fn = transformer.logits_fn
init_cache = transformer.init_cache


def _concat_inputs(params, cfg: ModelConfig, batch):
    img = batch["embeddings"].astype(cfg.compute_dtype)  # (B, P, d)
    tok = layers.embed(params["embed"], cfg,
                       batch["tokens"]).astype(cfg.compute_dtype)
    return jnp.concatenate([img, tok], axis=1)


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                   train: bool = False, impl=None):
    """Returns hidden states for the FULL (prefix + text) sequence; the
    training loss masks the prefix region."""
    h = _concat_inputs(params, cfg, batch)
    B, L, _ = h.shape
    positions = jnp.arange(L)[None]
    prefix = cfg.prefix_len

    def body(carry, lp):
        out = transformer.block_forward(lp, cfg, carry, positions=positions,
                                        window=cfg.sliding_window,
                                        prefix_len=prefix, impl=impl)
        return out, None

    scan_body = jax.checkpoint(body) if train else body
    h, _ = jax.lax.scan(scan_body, h, params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h)
    return h, jnp.zeros((), jnp.float32)


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            cache_size: Optional[int] = None, impl=None):
    h = _concat_inputs(params, cfg, batch)
    B, L, _ = h.shape            # L includes the image prefix
    window = cfg.sliding_window
    # callers budget cache_size in TEXT tokens; the image prefix rides along
    cache_size = (cache_size + cfg.prefix_len) if cache_size else L
    if window is not None:
        cache_size = min(cache_size, window)
    else:
        cache_size = max(cache_size, L)  # full attention never trims
    positions = jnp.arange(L)[None]

    def body(carry, lp):
        out, kv = transformer.block_prefill(
            lp, cfg, carry, positions=positions, window=window,
            prefix_len=cfg.prefix_len, cache_size=cache_size, impl=impl)
        return out, kv

    h, (k, v) = jax.lax.scan(body, h, params["blocks"])
    h = layers.apply_norm(params["ln_f"], cfg, h[:, -1:])
    logits = logits_fn(params, cfg, h[:, 0])
    return logits, {"k": k, "v": v, "len": jnp.asarray(L, jnp.int32)}


def prefill_chunk(params, cfg: ModelConfig, batch, cache, *, chunk_len,
                  impl=None):
    """Chunked prefill.  The FIRST chunk carries ``batch["embeddings"]``
    and processes the whole image prefix together with the first text
    bucket (prefix-LM bidirectionality makes the prefix indivisible:
    prefix rows attend to later prefix rows, so the prefix cannot span a
    chunk boundary).  Later chunks are plain causal text appends — every
    cached position (prefix included) is attendable, as in decode."""
    first = "embeddings" in batch
    if first:
        h = _concat_inputs(params, cfg, batch)     # (B, P + T, d)
        prefix = cfg.prefix_len
    else:
        h = layers.embed(params["embed"], cfg,
                         batch["tokens"]).astype(cfg.compute_dtype)
        prefix = 0
    eff_chunk = chunk_len + prefix                 # cache rows written
    window = cfg.sliding_window
    start = cache["len"]

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        x, kc, vc = transformer.block_prefill_chunk(
            lp, cfg, x, kc, vc, start, eff_chunk, window=window,
            prefix_len=prefix, impl=impl)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
        return (x, k_all, v_all), None

    (h, k, v), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.take_chunk_last(h, eff_chunk)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": cache["len"] + eff_chunk}


def prefill_chunk_paged(params, cfg: ModelConfig, batch, cache,
                        block_tables, *, chunk_len, block_size, impl=None):
    """Paged-native chunked prefill (see ``prefill_chunk``): the first
    chunk carries the whole bidirectional image prefix, and every written
    row — prefix and text alike — scatters straight into the arena page
    pools through the block table."""
    first = "embeddings" in batch
    if first:
        h = _concat_inputs(params, cfg, batch)     # (B, P + T, d)
        prefix = cfg.prefix_len
    else:
        h = layers.embed(params["embed"], cfg,
                         batch["tokens"]).astype(cfg.compute_dtype)
        prefix = 0
    eff_chunk = chunk_len + prefix                 # cache rows written
    window = cfg.sliding_window
    start = jnp.asarray(cache["len"], jnp.int32).reshape(-1)

    def body(carry, xs):
        x, k_all, v_all = carry
        lp, i = xs
        x = constrain_activation(x)
        kp = tree_index_layer(k_all, i)
        vp = tree_index_layer(v_all, i)
        xn = layers.apply_norm(lp["ln1"], cfg, x)
        a, kp, vp = layers.attention_chunk_paged(
            lp["attn"], cfg, xn, kp, vp, block_tables, start, eff_chunk,
            block_size=block_size, window=window, prefix_len=prefix,
            impl=impl)
        x = x + a
        x = x + layers.mlp(lp["mlp"], cfg,
                           layers.apply_norm(lp["ln2"], cfg, x))
        k_all = tree_update_layer(k_all, kp, i)
        v_all = tree_update_layer(v_all, vp, i)
        return (x, k_all, v_all), None

    (h, k, v), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    h = layers.take_chunk_last(h, eff_chunk)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"k": k, "v": v, "len": start + eff_chunk}


# decode: after prefill every cached position is attendable by new tokens
# (prefix bidirectionality only affects prefix-internal rows, which are
# already baked into the cache), so dense decode semantics apply directly
# — for the paged layout too.
decode_step = transformer.decode_step
decode_step_paged = transformer.decode_step_paged
