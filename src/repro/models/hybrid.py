"""Zamba2-style hybrid: Mamba-2 backbone with a single SHARED attention
block applied every ``attn_every`` SSM layers (zamba2-7b).

The shared block consumes concat(h, h0) (h0 = the original embeddings, the
Zamba trick) through one weight set reused at every application point, but
each application keeps its own KV cache.  Layer structure is a scan over
``n_apps`` groups of (attn_every mamba layers + shared attention), plus a
scanned tail of leftover mamba layers — HLO stays O(1) in depth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.quant import tree_index_layer, tree_update_layer
from . import layers, ssm, transformer
from .config import ModelConfig
from .sharding import constrain_activation


def _n_apps(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def _tail_layers(cfg: ModelConfig) -> int:
    return cfg.num_layers - _n_apps(cfg) * cfg.attn_every


def init_shared_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "ln_a": layers.init_norm(ks[0], cfg, dim=2 * cfg.d_model),
        "attn": layers.init_attention(ks[1], cfg, d_in=2 * cfg.d_model),
        "ln_m": layers.init_norm(ks[2], cfg),
        "mlp": layers.init_mlp(ks[3], cfg),
    }


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "embed": layers.init_embedding(ks[0], cfg),
        "mamba": transformer.stack_layer_params(
            ks[1], cfg.num_layers, lambda k: ssm.init_mamba_block(k, cfg)),
        "shared": init_shared_block(ks[2], cfg),
        "ln_f": layers.init_norm(ks[3], cfg),
    }


def _split_groups(cfg: ModelConfig, stacked):
    napps, every = _n_apps(cfg), cfg.attn_every
    head = jax.tree.map(
        lambda a: a[:napps * every].reshape(napps, every, *a.shape[1:]),
        stacked)
    tail = jax.tree.map(lambda a: a[napps * every:], stacked)
    return head, tail


def _shared_forward(shared, cfg: ModelConfig, h, h0, *, positions, window,
                    collect_kv: bool, cache_size: int = 0, impl=None):
    h = constrain_activation(h)
    xcat = jnp.concatenate([h, h0], axis=-1)
    xn = layers.apply_norm(shared["ln_a"], cfg, xcat)
    a, (k, v) = layers.attention(shared["attn"], cfg, xn, positions=positions,
                                 causal=True, window=window, impl=impl)
    h = h + a
    h = h + layers.mlp(shared["mlp"], cfg,
                       layers.apply_norm(shared["ln_m"], cfg, h))
    if not collect_kv:
        return h, None
    L = k.shape[1]
    if cache_size > L:
        pad = ((0, 0), (0, cache_size - L), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    elif cache_size and cache_size < L:
        k, v = k[:, L - cache_size:], v[:, L - cache_size:]
        shift = L % cache_size
        k, v = jnp.roll(k, shift, axis=1), jnp.roll(v, shift, axis=1)
    return h, (k, v)


def _shared_decode(shared, cfg: ModelConfig, h_t, h0_t, k_cache, v_cache,
                   cache_len, *, window, impl=None):
    S = k_cache.shape[1]
    eff_window = None if (window is None or S <= window) else window
    xcat = jnp.concatenate([h_t, h0_t], axis=-1)
    xn = layers.apply_norm(shared["ln_a"], cfg, xcat[:, None])[:, 0]
    a, k_cache, v_cache = layers.attention_decode(
        shared["attn"], cfg, xn, k_cache, v_cache, cache_len,
        window=eff_window, impl=impl)
    h_t = h_t + a
    xn = layers.apply_norm(shared["ln_m"], cfg, h_t[:, None])[:, 0]
    h_t = h_t + layers.mlp(shared["mlp"], cfg, xn)
    return h_t, k_cache, v_cache


def _shared_chunk(shared, cfg: ModelConfig, h, h0, k_cache, v_cache,
                  cache_len, chunk_len, *, window, impl=None):
    """Chunked-prefill pass through the shared attention block (multi-token
    sibling of ``_shared_decode``)."""
    h = constrain_activation(h)
    xcat = jnp.concatenate([h, h0], axis=-1)
    xn = layers.apply_norm(shared["ln_a"], cfg, xcat)
    a, k_cache, v_cache = layers.attention_chunk(
        shared["attn"], cfg, xn, k_cache, v_cache, cache_len, chunk_len,
        window=window, impl=impl)
    h = h + a
    h = h + layers.mlp(shared["mlp"], cfg,
                       layers.apply_norm(shared["ln_m"], cfg, h))
    return h, k_cache, v_cache


def _shared_decode_paged(shared, cfg: ModelConfig, h_t, h0_t, k_pages,
                         v_pages, block_tables, lens, live, *, block_size,
                         window, impl=None):
    """Paged-native ``_shared_decode``: the application's K/V stream
    through the block table, only the new row is written back."""
    xcat = jnp.concatenate([h_t, h0_t], axis=-1)
    xn = layers.apply_norm(shared["ln_a"], cfg, xcat[:, None])[:, 0]
    a, k_pages, v_pages = layers.attention_decode_paged(
        shared["attn"], cfg, xn, k_pages, v_pages, block_tables, lens,
        live, block_size=block_size, window=window, impl=impl)
    h_t = h_t + a
    xn = layers.apply_norm(shared["ln_m"], cfg, h_t[:, None])[:, 0]
    h_t = h_t + layers.mlp(shared["mlp"], cfg, xn)
    return h_t, k_pages, v_pages


def _shared_chunk_paged(shared, cfg: ModelConfig, h, h0, k_pages, v_pages,
                        block_tables, cache_len, chunk_len, *, block_size,
                        window, impl=None):
    """Paged-native ``_shared_chunk``."""
    h = constrain_activation(h)
    xcat = jnp.concatenate([h, h0], axis=-1)
    xn = layers.apply_norm(shared["ln_a"], cfg, xcat)
    a, k_pages, v_pages = layers.attention_chunk_paged(
        shared["attn"], cfg, xn, k_pages, v_pages, block_tables, cache_len,
        chunk_len, block_size=block_size, window=window, impl=impl)
    h = h + a
    h = h + layers.mlp(shared["mlp"], cfg,
                       layers.apply_norm(shared["ln_m"], cfg, h))
    return h, k_pages, v_pages


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                   train: bool = False, impl=None):
    tokens = batch["tokens"]
    B, L = tokens.shape
    h0 = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(L)[None]
    head, tail = _split_groups(cfg, params["mamba"])
    window = cfg.sliding_window

    def mamba_body(carry, lp):
        return ssm.mamba_block(lp, cfg, carry, impl=impl), None

    mb = jax.checkpoint(mamba_body) if train else mamba_body

    def group_body(carry, group_params):
        h, _ = jax.lax.scan(mb, carry, group_params)
        h, _ = _shared_forward(params["shared"], cfg, h, h0,
                               positions=positions, window=window,
                               collect_kv=False, impl=impl)
        return h, None

    h, _ = jax.lax.scan(group_body, h0, head)
    if _tail_layers(cfg):
        h, _ = jax.lax.scan(mb, h, tail)
    h = layers.apply_norm(params["ln_f"], cfg, h)
    return h, jnp.zeros((), jnp.float32)


def logits_fn(params, cfg: ModelConfig, hidden):
    return layers.unembed(params["embed"], cfg, hidden)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    base = ssm.init_cache(cfg, batch_size, max_len, dtype)
    window = cfg.sliding_window
    S = min(max_len, window) if window is not None else max_len
    kv_shape = (_n_apps(cfg), batch_size, S, cfg.num_kv_heads, cfg.head_dim)
    base["attn_k"] = jnp.zeros(kv_shape, dtype)
    base["attn_v"] = jnp.zeros(kv_shape, dtype)
    return base


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            cache_size: Optional[int] = None, impl=None):
    tokens = batch["tokens"]
    B, L = tokens.shape
    window = cfg.sliding_window
    kv_size = cache_size or L
    if window is not None:
        kv_size = min(kv_size, window)
    else:
        kv_size = max(kv_size, L)  # full attention never trims
    h0 = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(L)[None]
    head, tail = _split_groups(cfg, params["mamba"])

    def mamba_body(carry, lp):
        out, (tail_s, state) = ssm.mamba_block(lp, cfg, carry,
                                               return_state=True, impl=impl)
        return out, (tail_s, state)

    def group_body(carry, group_params):
        h, states = jax.lax.scan(mamba_body, carry, group_params)
        h, kv = _shared_forward(params["shared"], cfg, h, h0,
                                positions=positions, window=window,
                                collect_kv=True, cache_size=kv_size,
                                impl=impl)
        return h, (states, kv)

    h, (gstates, (ak, av)) = jax.lax.scan(group_body, h0, head)
    conv = gstates[0].reshape(-1, *gstates[0].shape[2:])
    ssd = gstates[1].reshape(-1, *gstates[1].shape[2:])
    if _tail_layers(cfg):
        h, (tconv, tssd) = jax.lax.scan(mamba_body, h, tail)
        conv = jnp.concatenate([conv, tconv], axis=0)
        ssd = jnp.concatenate([ssd, tssd], axis=0)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, -1:])
    logits = logits_fn(params, cfg, h[:, 0])
    cache = {"conv": conv, "ssd": ssd, "attn_k": ak, "attn_v": av,
             "len": jnp.asarray(L, jnp.int32)}
    return logits, cache


def prefill_chunk(params, cfg: ModelConfig, batch, cache, *, chunk_len,
                  impl=None):
    """Chunked prefill: mamba layers advance their recurrent state via
    ``ssm.mamba_block_chunk``; each shared-attention application appends
    the chunk's K/V to its own cache row (same carry-DUS layout as
    ``decode_step``, with a T-token block instead of one token)."""
    tokens = batch["tokens"]
    window = cfg.sliding_window
    h0 = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    napps, every = _n_apps(cfg), cfg.attn_every
    n_head = napps * every
    head, tail = _split_groups(cfg, params["mamba"])
    start = cache["len"]

    def mamba_body(carry, xs):
        h, conv_all, ssd_all = carry
        lp, i = xs
        conv = jax.lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
        ssd = jax.lax.dynamic_index_in_dim(ssd_all, i, 0, keepdims=False)
        h, conv, ssd = ssm.mamba_block_chunk(lp, cfg, h, conv, ssd,
                                             chunk_len, impl=impl)
        conv_all = jax.lax.dynamic_update_index_in_dim(
            conv_all, conv.astype(conv_all.dtype), i, 0)
        ssd_all = jax.lax.dynamic_update_index_in_dim(
            ssd_all, ssd.astype(ssd_all.dtype), i, 0)
        return (h, conv_all, ssd_all), None

    def group_body(carry, xs):
        h, conv_all, ssd_all, k_all, v_all = carry
        gp, g = xs
        idx = g * every + jnp.arange(every)
        (h, conv_all, ssd_all), _ = jax.lax.scan(
            mamba_body, (h, conv_all, ssd_all), (gp, idx))
        kc = jax.lax.dynamic_index_in_dim(k_all, g, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, g, 0, keepdims=False)
        h, kc, vc = _shared_chunk(params["shared"], cfg, h, h0, kc, vc,
                                  start, chunk_len, window=window,
                                  impl=impl)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, g, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, g, 0)
        return (h, conv_all, ssd_all, k_all, v_all), None

    carry0 = (h0, cache["conv"], cache["ssd"], cache["attn_k"],
              cache["attn_v"])
    (h, conv, ssd, ak, av), _ = jax.lax.scan(
        group_body, carry0, (head, jnp.arange(napps)))
    if _tail_layers(cfg):
        tail_idx = n_head + jnp.arange(_tail_layers(cfg))
        (h, conv, ssd), _ = jax.lax.scan(
            mamba_body, (h, conv, ssd), (tail, tail_idx))
    h = layers.take_chunk_last(h, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"conv": conv, "ssd": ssd, "attn_k": ak, "attn_v": av,
                    "len": cache["len"] + chunk_len}


def prefill_chunk_paged(params, cfg: ModelConfig, batch, cache,
                        block_tables, *, chunk_len, block_size, impl=None):
    """Paged-native chunked prefill: mamba conv/SSD state advances exactly
    as in ``prefill_chunk`` (per-slot state is never paged); each shared-
    attention application scatters its chunk K/V rows straight into its
    arena page pool through the block table."""
    tokens = batch["tokens"]
    window = cfg.sliding_window
    h0 = layers.embed(params["embed"], cfg, tokens).astype(cfg.compute_dtype)
    napps, every = _n_apps(cfg), cfg.attn_every
    n_head = napps * every
    head, tail = _split_groups(cfg, params["mamba"])
    start = jnp.asarray(cache["len"], jnp.int32).reshape(-1)

    def mamba_body(carry, xs):
        h, conv_all, ssd_all = carry
        lp, i = xs
        conv = jax.lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
        ssd = jax.lax.dynamic_index_in_dim(ssd_all, i, 0, keepdims=False)
        h, conv, ssd = ssm.mamba_block_chunk(lp, cfg, h, conv, ssd,
                                             chunk_len, impl=impl)
        conv_all = jax.lax.dynamic_update_index_in_dim(
            conv_all, conv.astype(conv_all.dtype), i, 0)
        ssd_all = jax.lax.dynamic_update_index_in_dim(
            ssd_all, ssd.astype(ssd_all.dtype), i, 0)
        return (h, conv_all, ssd_all), None

    def group_body(carry, xs):
        h, conv_all, ssd_all, k_all, v_all = carry
        gp, g = xs
        idx = g * every + jnp.arange(every)
        (h, conv_all, ssd_all), _ = jax.lax.scan(
            mamba_body, (h, conv_all, ssd_all), (gp, idx))
        kp = tree_index_layer(k_all, g)
        vp = tree_index_layer(v_all, g)
        h, kp, vp = _shared_chunk_paged(params["shared"], cfg, h, h0, kp,
                                        vp, block_tables, start, chunk_len,
                                        block_size=block_size,
                                        window=window, impl=impl)
        k_all = tree_update_layer(k_all, kp, g)
        v_all = tree_update_layer(v_all, vp, g)
        return (h, conv_all, ssd_all, k_all, v_all), None

    carry0 = (h0, cache["conv"], cache["ssd"], cache["attn_k"],
              cache["attn_v"])
    (h, conv, ssd, ak, av), _ = jax.lax.scan(
        group_body, carry0, (head, jnp.arange(napps)))
    if _tail_layers(cfg):
        tail_idx = n_head + jnp.arange(_tail_layers(cfg))
        (h, conv, ssd), _ = jax.lax.scan(
            mamba_body, (h, conv, ssd), (tail, tail_idx))
    h = layers.take_chunk_last(h, chunk_len)
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"conv": conv, "ssd": ssd, "attn_k": ak, "attn_v": av,
                    "len": start + chunk_len}


def decode_step(params, cfg: ModelConfig, token, cache, impl=None):
    """Carry-DUS cache updates throughout (see transformer.decode_step):
    mamba conv/ssd states indexed by the FLAT layer id, shared-attention
    caches by the application id — everything stays in one donated buffer."""
    window = cfg.sliding_window
    new_len = cache["len"] + 1
    h0 = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)
    napps, every = _n_apps(cfg), cfg.attn_every
    n_head = napps * every
    head, tail = _split_groups(cfg, params["mamba"])

    def mamba_body(carry, xs):
        h, conv_all, ssd_all = carry
        lp, i = xs
        conv = jax.lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
        ssd = jax.lax.dynamic_index_in_dim(ssd_all, i, 0, keepdims=False)
        h, conv, ssd = ssm.mamba_block_decode(lp, cfg, h, conv, ssd,
                                              impl=impl)
        conv_all = jax.lax.dynamic_update_index_in_dim(conv_all, conv, i, 0)
        ssd_all = jax.lax.dynamic_update_index_in_dim(
            ssd_all, ssd.astype(ssd_all.dtype), i, 0)
        return (h, conv_all, ssd_all), None

    def group_body(carry, xs):
        h, conv_all, ssd_all, k_all, v_all = carry
        gp, g = xs
        idx = g * every + jnp.arange(every)
        (h, conv_all, ssd_all), _ = jax.lax.scan(
            mamba_body, (h, conv_all, ssd_all), (gp, idx))
        kc = jax.lax.dynamic_index_in_dim(k_all, g, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, g, 0, keepdims=False)
        h, kc, vc = _shared_decode(params["shared"], cfg, h, h0, kc, vc,
                                   new_len, window=window, impl=impl)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, g, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, g, 0)
        return (h, conv_all, ssd_all, k_all, v_all), None

    carry0 = (h0, cache["conv"], cache["ssd"], cache["attn_k"],
              cache["attn_v"])
    (h, conv, ssd, ak, av), _ = jax.lax.scan(
        group_body, carry0, (head, jnp.arange(napps)))
    if _tail_layers(cfg):
        tail_idx = n_head + jnp.arange(_tail_layers(cfg))
        (h, conv, ssd), _ = jax.lax.scan(
            mamba_body, (h, conv, ssd), (tail, tail_idx))
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"conv": conv, "ssd": ssd, "attn_k": ak, "attn_v": av,
                    "len": new_len}


def decode_step_paged(params, cfg: ModelConfig, token, cache, block_tables,
                      live, *, block_size, impl=None):
    """Paged-native fused decode: the mamba backbone's conv/SSD state is
    untouched (state side-channel), each shared-attention application
    streams its K/V through the block table and writes one new row per
    live slot."""
    window = cfg.sliding_window
    lens = jnp.asarray(cache["len"], jnp.int32)
    live = jnp.asarray(live, bool)
    h0 = layers.embed(params["embed"], cfg, token).astype(cfg.compute_dtype)
    napps, every = _n_apps(cfg), cfg.attn_every
    n_head = napps * every
    head, tail = _split_groups(cfg, params["mamba"])

    def mamba_body(carry, xs):
        h, conv_all, ssd_all = carry
        lp, i = xs
        conv = jax.lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
        ssd = jax.lax.dynamic_index_in_dim(ssd_all, i, 0, keepdims=False)
        h, conv, ssd = ssm.mamba_block_decode(lp, cfg, h, conv, ssd,
                                              impl=impl)
        conv_all = jax.lax.dynamic_update_index_in_dim(conv_all, conv, i, 0)
        ssd_all = jax.lax.dynamic_update_index_in_dim(
            ssd_all, ssd.astype(ssd_all.dtype), i, 0)
        return (h, conv_all, ssd_all), None

    def group_body(carry, xs):
        h, conv_all, ssd_all, k_all, v_all = carry
        gp, g = xs
        idx = g * every + jnp.arange(every)
        (h, conv_all, ssd_all), _ = jax.lax.scan(
            mamba_body, (h, conv_all, ssd_all), (gp, idx))
        kp = tree_index_layer(k_all, g)
        vp = tree_index_layer(v_all, g)
        h, kp, vp = _shared_decode_paged(params["shared"], cfg, h, h0, kp,
                                         vp, block_tables, lens, live,
                                         block_size=block_size,
                                         window=window, impl=impl)
        k_all = tree_update_layer(k_all, kp, g)
        v_all = tree_update_layer(v_all, vp, g)
        return (h, conv_all, ssd_all, k_all, v_all), None

    carry0 = (h0, cache["conv"], cache["ssd"], cache["attn_k"],
              cache["attn_v"])
    (h, conv, ssd, ak, av), _ = jax.lax.scan(
        group_body, carry0, (head, jnp.arange(napps)))
    if _tail_layers(cfg):
        tail_idx = n_head + jnp.arange(_tail_layers(cfg))
        (h, conv, ssd), _ = jax.lax.scan(
            mamba_body, (h, conv, ssd), (tail, tail_idx))
    h = layers.apply_norm(params["ln_f"], cfg, h[:, None])[:, 0]
    logits = logits_fn(params, cfg, h)
    return logits, {"conv": conv, "ssd": ssd, "attn_k": ak, "attn_v": av,
                    "len": jnp.where(live, lens + 1, lens)}
