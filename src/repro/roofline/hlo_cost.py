"""Trip-count-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — our
models scan over layers/microbatches/loss-chunks, so its flops are low by
~2 orders of magnitude (verified: a 10-step scanned matmul reports 1/10th
of the unrolled flops).  This module re-derives per-device costs by parsing
the optimized HLO and multiplying each while body by its
``known_trip_count`` backend annotation:

  flops  — 2*M*N*K for every ``dot`` (contraction sizes from operand
           shapes), recursively through fusions/calls/whiles;
  bytes  — operand + result bytes of every top-level instruction (fusion
           internals excluded: they live in registers/VMEM), i.e. traffic
           at fusion boundaries, matching XLA's own "bytes accessed" model;
  collectives — per-device wire bytes with ring-algorithm factors.

This is the per-DEVICE cost of the SPMD-partitioned module (the HLO we
parse is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "iota", "after-all", "opt-barrier",
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->.*\{\s*$")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+"
    r"((?:\([^()]*\))|(?:[\w\[\],]+(?:\{[\d,]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALLEE_ATTRS = ("body", "condition", "calls", "to_apply")


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_result_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.coll_wire_bytes += other.coll_wire_bytes * times
        self.coll_result_bytes += other.coll_result_bytes * times
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * times


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    opcode: str
    rest: str                     # operand list + attrs (rest of line)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[_Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # -- parsing ------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            if cur is None:
                m = _COMP_HDR.match(raw)
                if m:
                    cur = m.group(2)
                    self.computations[cur] = []
                    if m.group(1):
                        self.entry = cur
                continue
            if raw.startswith("}"):
                cur = None
                continue
            m = _INSTR.match(raw)
            if m:
                self.computations[cur].append(
                    _Instr(name=m.group(1), shape=m.group(2),
                           opcode=m.group(3), rest=m.group(4)))

    # -- helpers --------------------------------------------------------------
    def _symbols(self, comp: str) -> Dict[str, str]:
        return {i.name: i.shape for i in self.computations.get(comp, ())}

    @staticmethod
    def _operands(instr: _Instr) -> List[str]:
        # operand refs appear before the first "), " attr separator
        depth, end = 0, len(instr.rest)
        for idx, ch in enumerate(instr.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = idx
                    break
                depth -= 1
        return _OPERAND.findall(instr.rest[:end])

    def _callees(self, instr: _Instr) -> List[str]:
        out = []
        for attr in _CALLEE_ATTRS:
            for m in re.finditer(rf"{attr}=%?([\w\.\-]+)", instr.rest):
                out.append(m.group(1))
        return out

    # -- per-instruction costs ---------------------------------------------
    def _dot_flops(self, instr: _Instr, symbols: Dict[str, str]) -> float:
        result_elems = 0
        for _, dims in _shape_dims(instr.shape):
            n = 1
            for d in dims:
                n *= d
            result_elems += n
        ops = self._operands(instr)
        k = 1
        if ops:
            lhs_shape = symbols.get(ops[0], "")
            dims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                               instr.rest)
            sd = _shape_dims(lhs_shape)
            if dims_m and sd:
                lhs_dims = sd[0][1]
                for ci in (int(x) for x in dims_m.group(1).split(",") if x):
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
        return 2.0 * result_elems * k

    @staticmethod
    def _group_size(instr: _Instr) -> int:
        m = _GROUPS.search(instr.rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST.search(instr.rest)
        if m:
            return len([x for x in m.group(1).split(",") if x.strip()])
        return 2

    @staticmethod
    def _wire_factor(op: str, n: int) -> float:
        if n <= 1:
            return 0.0
        if op == "all-reduce":
            return 2.0 * (n - 1) / n
        if op == "all-gather":
            return (n - 1) / n
        if op == "reduce-scatter":
            return float(n - 1)          # input = n x result
        if op == "all-to-all":
            return (n - 1) / n
        return 1.0                       # collective-permute

    # -- computation cost (memoized, trip-count aware) ----------------------
    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()        # cycle guard
        total = Cost()
        symbols = self._symbols(name)
        for instr in self.computations.get(name, ()):
            op = instr.opcode
            base = op.replace("-start", "")
            if op in _NO_TRAFFIC_OPS or op.endswith("-done"):
                continue
            # traffic at fusion boundaries
            rb = _shape_bytes(instr.shape)
            ob = sum(_shape_bytes(symbols.get(o, "")) for o in
                     self._operands(instr))
            total.bytes += rb + ob
            if op == "dot":
                total.flops += self._dot_flops(instr, symbols)
            elif base in COLLECTIVE_OPS:
                n = self._group_size(instr)
                total.coll_result_bytes += rb
                total.coll_wire_bytes += rb * self._wire_factor(base, n)
                total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
            elif op == "while":
                callees = {a: m for a in ("body", "condition")
                           for m in re.findall(rf"{a}=%?([\w\.\-]+)",
                                               instr.rest)}
                trip = 1
                tm = _TRIP.search(instr.rest)
                if tm:
                    trip = int(tm.group(1))
                for comp in self._callees(instr):
                    total.add(self.computation_cost(comp), times=trip)
            elif op == "fusion":
                # internals live in registers: count only embedded dots
                for comp in self._callees(instr):
                    sub = self.computation_cost(comp)
                    total.flops += sub.flops
            elif op in ("call", "conditional", "custom-call", "map",
                        "reduce", "reduce-window", "sort", "scatter",
                        "select-and-scatter", "async-start"):
                heavy = ("call", "conditional", "async-start", "map")
                if op in heavy:
                    for comp in self._callees(instr):
                        total.add(self.computation_cost(comp))
                else:
                    # reducers/comparators: flops negligible, traffic already
                    # counted via operands/result above
                    pass
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            # fall back: the computation with the most instructions
            self.entry = max(self.computations,
                             key=lambda c: len(self.computations[c]))
        return self.computation_cost(self.entry)


def analyze_hlo_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
