"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), all in SECONDS:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = wire_bytes_per_device / ICI_link_bandwidth

Sources: ``compiled.cost_analysis()`` reports PER-DEVICE flops/bytes for
the partitioned module (verified empirically — a (16,64)@(64,128) matmul
over 8 devices reports 32768 = global/8 flops).  Collective bytes are NOT
in cost_analysis: we parse the optimized HLO, take each collective op's
per-device result-shard bytes, and convert to wire bytes with the standard
ring-algorithm factors.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s
ICI_BW = 50e9                # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


# ring-algorithm wire factors, applied to the per-device RESULT bytes
def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    count: int = 0
    result_bytes: float = 0.0      # per-device shard bytes, summed over ops
    wire_bytes: float = 0.0        # ring-adjusted bytes on the wire

    def merge(self, other: "CollectiveStats") -> None:
        self.count += other.count
        self.result_bytes += other.result_bytes
        self.wire_bytes += other.wire_bytes


def parse_collectives(hlo_text: str) -> Dict[str, CollectiveStats]:
    """Per-op-kind collective statistics from optimized HLO text.

    ``-start`` ops are counted; their paired ``-done`` lines carry no shape
    of their own in the tuple position so double-count risk is low, but we
    also skip lines with ``-done(`` explicitly."""
    out: Dict[str, CollectiveStats] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_text, op = m.group(1), m.group(2)
        bytes_ = _shape_bytes(shape_text)
        n = _group_size(line)
        st = out.setdefault(op, CollectiveStats())
        st.count += 1
        st.result_bytes += bytes_
        st.wire_bytes += bytes_ * _wire_factor(op, n)
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    chips: int
    flops_per_device: float
    bytes_per_device: float          # analytic TPU-fusion HBM traffic
    collective_wire_bytes: float     # per device
    collective_counts: Dict[str, int]
    memory_stats: Dict[str, float]
    model_flops: float = 0.0         # 6·N·D (train) or 2·N·D (inference)
    hlo_bytes_per_device: float = 0.0  # raw HLO-buffer bytes (cross-check;
    #                                    CPU fusion granularity inflates it)
    traffic_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops x chips): how much compiled compute is
        'useful'.  <1 means remat/dispatch/padding overhead; >1 means the
        compiler found algebraic savings (rare) or the analytic model
        overcounts (e.g. SWA)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> Dict:
        return {
            "name": self.name, "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "traffic_breakdown": self.traffic_breakdown,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_counts": self.collective_counts,
            "memory_stats": self.memory_stats,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze_compiled(name: str, compiled, chips: int, *,
                     model_flops: float = 0.0,
                     hlo_text: Optional[str] = None,
                     analytic_traffic=None) -> Roofline:
    from .hlo_cost import analyze_hlo_text
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # trip-count-aware per-device costs (XLA's cost_analysis counts while
    # bodies once — see hlo_cost.py; kept in memory_stats as cross-ref)
    cost = analyze_hlo_text(text)
    flops = cost.flops
    hlo_bytes = cost.bytes
    bytes_ = analytic_traffic.total if analytic_traffic is not None \
        else cost.bytes
    wire = cost.coll_wire_bytes
    counts = {k: int(v) for k, v in cost.coll_counts.items()}
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    mem = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        mem[field] = float(getattr(ma, field, 0) or 0)
    mem["total_hbm_bytes"] = (mem["argument_size_in_bytes"]
                              + mem["output_size_in_bytes"]
                              + mem["temp_size_in_bytes"]
                              - mem["alias_size_in_bytes"])
    mem["xla_flops_once"] = float(ca.get("flops", 0.0))
    mem["xla_bytes_once"] = float(ca.get("bytes accessed", 0.0))
    return Roofline(name=name, chips=chips, flops_per_device=flops,
                    bytes_per_device=bytes_, collective_wire_bytes=wire,
                    collective_counts=counts, memory_stats=mem,
                    model_flops=model_flops,
                    hlo_bytes_per_device=hlo_bytes,
                    traffic_breakdown=(analytic_traffic.to_dict()
                                       if analytic_traffic else {}))


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for inference
    (D = tokens processed; decode D = batch)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch     # decode: one token per sequence


def format_table(rows: List[Roofline]) -> str:
    hdr = (f"{'pair':42s} {'chips':>5s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'dominant':>10s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.name:42s} {r.chips:5d} {r.compute_s:10.4g} "
            f"{r.memory_s:10.4g} {r.collective_s:10.4g} {r.dominant:>10s} "
            f"{r.useful_flops_ratio:7.3f}")
    return "\n".join(lines)
