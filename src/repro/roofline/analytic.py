"""Analytic per-device HBM traffic model (the roofline memory term).

Why analytic: the dry-run compiles on the CPU backend, whose fusion
granularity materializes flash-attention block transients (s/p tiles) to
buffers; counting HLO buffer traffic therefore over-states TPU HBM bytes
by ~2 orders of magnitude (on TPU those tiles live in VMEM inside the
Pallas kernel).  FLOPs and collective bytes are fusion-invariant, so those
come from the trip-count-aware HLO analyzer (hlo_cost.py); bytes come from
this explicit model of what a TPU execution streams from/to HBM.  The raw
HLO-buffer bytes are recorded alongside as a cross-check.

All quantities are PER DEVICE per executed step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig, ShapeSpec

BF16 = 2
F32 = 4
FLASH_BLOCK = 512          # ref/kernel block size: kv re-read factor = Lq/blk


@dataclasses.dataclass
class TrafficBreakdown:
    weights: float = 0.0       # streamed weight reads (gathered copies)
    optimizer: float = 0.0     # grads + moments r/w
    activations: float = 0.0   # saved/rematted layer carries
    kv_rereads: float = 0.0    # flash attention K/V streaming
    cache: float = 0.0         # decode cache read + token write
    logits: float = 0.0        # lm-head + loss traffic
    embeds: float = 0.0        # embedding gathers + stub inputs

    @property
    def total(self) -> float:
        return (self.weights + self.optimizer + self.activations
                + self.kv_rereads + self.cache + self.logits + self.embeds)

    def to_dict(self) -> Dict[str, float]:
        return {k: getattr(self, k) for k in
                ("weights", "optimizer", "activations", "kv_rereads",
                 "cache", "logits", "embeds")} | {"total": self.total}


def _vocab_shard(cfg: ModelConfig, model_ax: int) -> int:
    return model_ax if cfg.vocab_size % model_ax == 0 else 1


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // max(1, cfg.attn_every)
    if cfg.family == "audio":
        return cfg.num_layers + cfg.encoder_layers   # + cross attn ~ self
    return cfg.num_layers


def traffic(cfg: ModelConfig, shape: ShapeSpec, *, data_ax: int,
            model_ax: int, pod_ax: int = 1, microbatches: int = 1,
            optimizer: str = "adamw", loss_chunk: int = 512,
            fsdp: bool = True, serve_2d_tp: bool = False) -> TrafficBreakdown:
    chips = data_ax * model_ax * pod_ax
    P = cfg.param_count()
    N_layers = max(1, cfg.num_layers)
    d = cfg.d_model
    tb = TrafficBreakdown()

    # tokens this device processes per step
    batch_shards = data_ax * pod_ax if shape.global_batch % (
        data_ax * pod_ax) == 0 else 1
    B_dev = shape.global_batch / batch_shards

    if shape.kind == "train":
        passes = 3.0  # fwd + remat-recompute + bwd weight reads
        # each pass streams the model-axis shard of every weight (gathered
        # over data when fsdp), once per microbatch
        tb.weights = passes * microbatches * P * BF16 / model_ax
        opt_bytes = {"adamw": (4 + 4) + (8 + 8),       # grad r/w + m,v r/w
                     "adafactor": (4 + 4) + 2.2}[optimizer]
        tb.optimizer = P * opt_bytes / chips
        toks_dev = shape.tokens / batch_shards
        # saved carry per layer (sharded over model too via the constraint)
        tb.activations = 4.0 * toks_dev * d * BF16 * N_layers / model_ax
        # flash kv re-reads: per attn layer, K+V streamed once per q block
        nq = max(1, shape.seq_len // FLASH_BLOCK)
        window = cfg.sliding_window
        lk_eff = min(shape.seq_len, (window + FLASH_BLOCK)) if window \
            else shape.seq_len
        kv_bytes = (B_dev * lk_eff * cfg.num_kv_heads * cfg.head_dim
                    * 2 * BF16)
        rereads_per_block = min(nq, max(
            1, lk_eff // FLASH_BLOCK)) if window else nq
        tb.kv_rereads = (_attn_layers(cfg) / max(1, N_layers) * N_layers
                         * kv_bytes * rereads_per_block * 3.0  # fwd+rec+bwd
                         / model_ax)
        vshard = _vocab_shard(cfg, model_ax)
        tb.logits = 3.0 * toks_dev * cfg.vocab_size * F32 / vshard
        tb.embeds = 2.0 * toks_dev * d * BF16
    elif shape.kind == "prefill":
        tb.weights = P * BF16 / model_ax
        toks_dev = shape.tokens / batch_shards
        tb.activations = toks_dev * d * BF16 * N_layers / model_ax
        nq = max(1, shape.seq_len // FLASH_BLOCK)
        window = cfg.sliding_window
        lk_eff = min(shape.seq_len, window + FLASH_BLOCK) if window \
            else shape.seq_len
        kv_bytes = (B_dev * lk_eff * cfg.num_kv_heads * cfg.head_dim
                    * 2 * BF16)
        rereads = max(1, lk_eff // FLASH_BLOCK) if window else nq
        tb.kv_rereads = _attn_layers(cfg) * kv_bytes * rereads / model_ax
        # cache write
        tb.cache = _attn_layers(cfg) * kv_bytes / model_ax
        vshard = _vocab_shard(cfg, model_ax)
        tb.logits = (shape.global_batch / batch_shards) * cfg.vocab_size \
            * F32 / vshard
        tb.embeds = toks_dev * d * BF16
    else:  # decode: ONE token against a seq_len-deep cache
        if serve_2d_tp:
            # weights stay shard-resident (no FSDP gather): each chip
            # streams only its 1/chips shard; batch replicated
            tb.weights = P * BF16 / chips
            B_dev = shape.global_batch
        else:
            tb.weights = P * BF16 / model_ax    # gathered copy per step
        window = cfg.sliding_window
        S = min(shape.seq_len, window) if window else shape.seq_len
        if cfg.family in ("ssm", "hybrid"):
            ssm_state = (cfg.num_layers * B_dev * cfg.ssm_nheads
                         * cfg.ssm_headdim * cfg.ssm_state * F32)
            tb.cache += 2.0 * ssm_state      # read + write
        kv_bytes = (B_dev * S * cfg.num_kv_heads * cfg.head_dim * 2 * BF16)
        cache_shard = (model_ax * data_ax * pod_ax) if serve_2d_tp \
            else model_ax
        tb.cache += _attn_layers(cfg) * kv_bytes / cache_shard
        if cfg.family == "audio":
            xkv = (B_dev * cfg.encoder_len * cfg.num_kv_heads * cfg.head_dim
                   * 2 * BF16)
            tb.cache += cfg.num_layers * xkv / model_ax
        vshard = _vocab_shard(cfg, model_ax)
        tb.logits = B_dev * cfg.vocab_size * F32 / vshard
        tb.embeds = B_dev * d * BF16
    return tb
