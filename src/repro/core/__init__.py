"""EPARA's primary contribution: task-categorized parallelism allocation,
distributed request handling, state-aware submodular service placement,
and ring information synchronization (paper §3)."""
from .allocator import (DPGroupRouter, MeshPlan, ParallelPlan, allocate,
                        categorize, mesh_submesh, plan_goodput)
from .categories import (ALL_CATEGORIES, CAT_FREQ_MULTI, CAT_FREQ_SINGLE,
                         CAT_LAT_MULTI, CAT_LAT_SINGLE, GPUSpec, Operator,
                         Request, Sensitivity, ServerSpec, ServiceSpec,
                         TaskCategory, operators_for)
from .cluster import EdgeCloudControlPlane, EdgeDevice
from .goodput import GoodputMeter, frequency_credit, latency_satisfied
from .handler import (Decision, Outcome, RequestHandler, ServerView,
                      ServiceState)
from .placement import (EPSILON_SERVER, PlacementProblem,
                        approximation_bound, evaluate, matroid_count,
                        place_lfu, place_lru, place_mfu, spf, sssp)
from .sync import ParameterServerSync, RingSynchronizer

__all__ = [n for n in dir() if not n.startswith("_")]
