"""State-aware submodular service placement — SSSP (§3.3, Alg. 1 + 2).

A *placement* x_{ln} deploys service l's full ParallelPlan on server n.
φ(Θ) (Eq. 2) counts requests satisfied over the period T under the §3.2
handling strategy; we evaluate it with a deterministic fluid model of that
strategy (local-first, then offload spillover), which is monotone and
submodular in the placement set — property-tested in
tests/test_placement.py and the basis of the 1/(1+P) bound (Appendix A).

Algorithm 1 (SSSP) runs three SPF stages:
  S1 — priority list X̄ (leased GPUs / parallel-intensive services first),
       list semantics, continues on φ-equal steps;
  S2 — all (service, server) pairs, set semantics, strict improvement;
  S3 — the hypothetical aggregated server ε (cross-server parallelism).

Algorithm 2 (SPF) is greedy submodular maximization; ``lazy=True`` uses
CELF lazy evaluation (valid by submodularity) — the beyond-paper speedup
that keeps single-placement latency <200 ms at large N (Fig. 17c).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from . import costmodel as cm
from .allocator import ParallelPlan, plan_goodput
from .categories import GPUSpec, ServerSpec, ServiceSpec

EPSILON_SERVER = -1   # the hypothetical aggregated server ε (S3)

Placement = Tuple[str, int]          # (service, server-id | EPSILON_SERVER)


@dataclasses.dataclass
class PlacementProblem:
    services: Dict[str, ServiceSpec]
    plans: Dict[str, ParallelPlan]
    servers: List[ServerSpec]
    demand: Dict[Tuple[str, int], float]     # reqs/s arriving at server n
    period_s: float = 60.0
    priority_list: Sequence[Placement] = ()  # X̄ for S1
    offload_efficiency: float = 0.9          # handler spillover discount

    def server_by_id(self) -> Dict[int, ServerSpec]:
        return {s.sid: s for s in self.servers}

    # resource footprint of one placement (the two matroid dimensions)
    def compute_units(self, svc: str) -> float:
        plan = self.plans[svc]
        return plan.gpus / max(1, plan.mt)

    def vram_units(self, svc: str) -> float:
        plan = self.plans[svc]
        spec = self.services[svc]
        gpu = self.servers[0].gpu if self.servers else GPUSpec()
        return cm.vram_fraction(spec, gpu, plan.mp) * plan.gpus


# ---------------------------------------------------------------------------
# feasibility (matroid independence)
# ---------------------------------------------------------------------------

def _budgets(problem: PlacementProblem,
             placements: Iterable[Placement]) -> Dict[int, Tuple[float, float]]:
    """Remaining (compute, vram) units per server under ``placements``."""
    rem = {s.sid: (float(s.num_gpus), float(s.num_gpus))
           for s in problem.servers}
    eps_compute = 0.0
    for svc, sid in placements:
        if sid == EPSILON_SERVER:
            eps_compute += problem.compute_units(svc)
            continue
        c, v = rem[sid]
        rem[sid] = (c - problem.compute_units(svc),
                    v - problem.vram_units(svc))
    # ε's budget = pooled leftovers
    pooled = sum(max(0.0, c) for c, _ in rem.values())
    rem[EPSILON_SERVER] = (pooled - eps_compute, pooled - eps_compute)
    return rem


def feasible(problem: PlacementProblem, placements: Sequence[Placement],
             candidate: Placement) -> bool:
    if candidate in placements:
        return False
    svc, sid = candidate
    rem = _budgets(problem, placements)
    c, v = rem[sid]
    if sid == EPSILON_SERVER:
        return problem.compute_units(svc) <= c + 1e-9
    return (problem.compute_units(svc) <= c + 1e-9
            and problem.vram_units(svc) <= v + 1e-9)


# ---------------------------------------------------------------------------
# φ — fluid evaluation of the §3.2 handling strategy (Eq. 2)
# ---------------------------------------------------------------------------

def evaluate(problem: PlacementProblem,
             placements: Sequence[Placement]) -> float:
    """Satisfied requests over the period under local-first + spillover."""
    if not problem.servers:
        return 0.0
    gpu = problem.servers[0].gpu
    cap: Dict[Tuple[str, int], float] = {}
    for svc, sid in placements:
        spec = problem.services[svc]
        plan = problem.plans[svc]
        g = plan_goodput(spec, gpu, plan,
                         cross_server=(sid == EPSILON_SERVER))
        cap[(svc, sid)] = cap.get((svc, sid), 0.0) + g

    total = 0.0
    for svc in problem.services:
        local_sat = 0.0
        leftover_demand = 0.0
        leftover_cap = cap.get((svc, EPSILON_SERVER), 0.0)
        for server in problem.servers:
            d = problem.demand.get((svc, server.sid), 0.0)
            c = cap.get((svc, server.sid), 0.0)
            s = min(d, c)
            local_sat += s
            leftover_demand += d - s
            leftover_cap += c - s
        # offloaded requests satisfy at a discount (transfer latency eats
        # into the SLO budget) — this is what makes local placement near
        # demand strictly better and the evaluator "state-aware".
        offload_sat = problem.offload_efficiency * min(leftover_demand,
                                                       leftover_cap)
        total += local_sat + offload_sat
    return total * problem.period_s


# ---------------------------------------------------------------------------
# incremental φ — O(1) marginal gains (same math as ``evaluate``; equality
# is property-tested).  This is what keeps one SSSP round <200 ms at large
# N (Fig. 17c): the greedy needs |candidates| gain queries per selection.
# ---------------------------------------------------------------------------

class PhiState:
    def __init__(self, problem: PlacementProblem,
                 theta0: Sequence[Placement] = ()):
        self.p = problem
        gpu = problem.servers[0].gpu if problem.servers else GPUSpec()
        self._g = {svc: plan_goodput(problem.services[svc], gpu,
                                     problem.plans[svc])
                   for svc in problem.services}
        self._g_eps = {svc: plan_goodput(problem.services[svc], gpu,
                                         problem.plans[svc],
                                         cross_server=True)
                       for svc in problem.services}
        self.cap: Dict[Placement, float] = {}
        self.local_sat: Dict[str, float] = {s: 0.0 for s in problem.services}
        self.total_cap: Dict[str, float] = {s: 0.0 for s in problem.services}
        self.eps_cap: Dict[str, float] = {s: 0.0 for s in problem.services}
        self.total_demand: Dict[str, float] = {s: 0.0
                                               for s in problem.services}
        for (svc, sid), d in problem.demand.items():
            if svc in self.total_demand:
                self.total_demand[svc] += d
        # feasibility budgets, maintained incrementally
        self.rem: Dict[int, List[float]] = {
            s.sid: [float(s.num_gpus), float(s.num_gpus)]
            for s in problem.servers}
        self.placed: set = set()
        for delta in theta0:
            self.add(delta)

    # -- φ ----------------------------------------------------------------
    def _svc_phi(self, svc: str, local_sat: float, total_cap: float,
                 eps_cap: float) -> float:
        lo_d = self.total_demand[svc] - local_sat
        lo_c = (total_cap - local_sat) + eps_cap
        return local_sat + self.p.offload_efficiency * min(lo_d, lo_c)

    def total(self) -> float:
        out = 0.0
        for svc in self.p.services:
            out += self._svc_phi(svc, self.local_sat[svc],
                                 self.total_cap[svc], self.eps_cap[svc])
        return out * self.p.period_s

    def gain(self, delta: Placement) -> float:
        svc, sid = delta
        before = self._svc_phi(svc, self.local_sat[svc],
                               self.total_cap[svc], self.eps_cap[svc])
        if sid == EPSILON_SERVER:
            after = self._svc_phi(svc, self.local_sat[svc],
                                  self.total_cap[svc],
                                  self.eps_cap[svc] + self._g_eps[svc])
        else:
            g = self._g[svc]
            d = self.p.demand.get((svc, sid), 0.0)
            old_c = self.cap.get(delta, 0.0)
            dl = min(d, old_c + g) - min(d, old_c)
            after = self._svc_phi(svc, self.local_sat[svc] + dl,
                                  self.total_cap[svc] + g,
                                  self.eps_cap[svc])
        return (after - before) * self.p.period_s

    def add(self, delta: Placement) -> None:
        svc, sid = delta
        if sid == EPSILON_SERVER:
            self.eps_cap[svc] += self._g_eps[svc]
            # ε consumes pooled leftovers: charge the least-loaded servers
            need = self.p.compute_units(svc)
            for sid2 in sorted(self.rem, key=lambda s: -self.rem[s][0]):
                take = min(need, max(0.0, self.rem[sid2][0]))
                self.rem[sid2][0] -= take
                need -= take
                if need <= 1e-9:
                    break
        else:
            g = self._g[svc]
            d = self.p.demand.get((svc, sid), 0.0)
            old_c = self.cap.get(delta, 0.0)
            self.local_sat[svc] += min(d, old_c + g) - min(d, old_c)
            self.total_cap[svc] += g
            self.cap[delta] = old_c + g
            self.rem[sid][0] -= self.p.compute_units(svc)
            self.rem[sid][1] -= self.p.vram_units(svc)
        self.placed.add(delta)

    def feasible(self, delta: Placement) -> bool:
        if delta in self.placed:
            return False
        svc, sid = delta
        if sid == EPSILON_SERVER:
            pooled = sum(max(0.0, c) for c, _ in self.rem.values())
            return self.p.compute_units(svc) <= pooled + 1e-9
        c, v = self.rem[sid]
        return (self.p.compute_units(svc) <= c + 1e-9
                and self.p.vram_units(svc) <= v + 1e-9)


# ---------------------------------------------------------------------------
# Algorithm 2 — submodular placement for full models (SPF)
# ---------------------------------------------------------------------------

def spf(problem: PlacementProblem, candidates: Sequence[Placement],
        theta0: Sequence[Placement], *, list_semantics: bool = False,
        allow_equal: bool = False, lazy: bool = True) -> List[Placement]:
    """Greedy: repeatedly add the feasible candidate with the largest
    marginal gain; stop when gain is non-positive (S1: negative).  All gain
    queries go through the O(1) incremental PhiState (identical to
    ``evaluate`` — property-tested)."""
    theta = list(theta0)
    state = PhiState(problem, theta0)

    if lazy and not list_semantics:
        return _spf_lazy(problem, candidates, theta, state,
                         allow_equal=allow_equal)

    remaining = list(candidates)
    while True:
        best_gain, best = -math.inf, None
        for delta in remaining:
            if list_semantics and delta in theta:
                continue
            if not state.feasible(delta):
                continue
            gain = state.gain(delta)
            if gain > best_gain:
                best_gain, best = gain, delta
        if best is None:
            break
        if best_gain < 0 or (best_gain == 0 and not allow_equal):
            break
        theta.append(best)
        state.add(best)
        if list_semantics:
            remaining = [c for c in remaining if c != best]
        if best_gain == 0 and allow_equal:
            # φ-equal steps may continue under S1 (>=) but a full sweep of
            # zero gains cannot improve further — stop after one pass.
            allow_equal = False
    return theta


def _spf_lazy(problem: PlacementProblem, candidates: Sequence[Placement],
              theta: List[Placement], state: "PhiState", *,
              allow_equal: bool) -> List[Placement]:
    """CELF lazy greedy — marginal gains only shrink (submodularity), so a
    stale upper bound at the heap top that is still the max after refresh
    is the true argmax."""
    heap: List[Tuple[float, int, Placement]] = []
    for i, delta in enumerate(candidates):
        heap.append((-state.gain(delta), i, delta))
    heapq.heapify(heap)
    while heap:
        neg_gain, order, delta = heapq.heappop(heap)
        if -neg_gain <= 0 and not (allow_equal and -neg_gain == 0):
            break
        if delta in theta or not state.feasible(delta):
            continue
        fresh = state.gain(delta)
        if heap and fresh < -heap[0][0] - 1e-12:
            # keep the candidate's original index as the tiebreak: the old
            # id(delta) key made equal-gain pops follow allocation
            # addresses, so placements (and every downstream goodput
            # figure) varied run to run
            heapq.heappush(heap, (-fresh, order, delta))
            continue
        if fresh <= 0 and not (allow_equal and fresh == 0):
            break
        theta.append(delta)
        state.add(delta)
    return theta


# ---------------------------------------------------------------------------
# Algorithm 1 — state-aware service placement (SSSP)
# ---------------------------------------------------------------------------

def sssp(problem: PlacementProblem, *, lazy: bool = True,
         include_epsilon: bool = True) -> List[Placement]:
    theta: List[Placement] = []
    # S1: priority list X̄ (list semantics, >= continuation)
    if problem.priority_list:
        theta = spf(problem, list(problem.priority_list), theta,
                    list_semantics=True, allow_equal=True, lazy=False)
    # S2: all (service, server) pairs
    all_pairs = [(svc, s.sid) for svc in problem.services
                 for s in problem.servers]
    theta = spf(problem, all_pairs, theta, lazy=lazy)
    # S3: hypothetical aggregated server ε for cross-server parallelism
    if include_epsilon:
        eps_pairs = [(svc, EPSILON_SERVER) for svc, spec
                     in problem.services.items()
                     if problem.plans[svc].mp > 1]
        theta = spf(problem, eps_pairs, theta, lazy=lazy)
    return theta


# ---------------------------------------------------------------------------
# approximation bound (Eq. 3 / Appendix A)
# ---------------------------------------------------------------------------

def matroid_count(problem: PlacementProblem) -> int:
    """P = ceil(max a / min a>0) + ceil(max b / min b>0)."""
    a = [problem.compute_units(svc) for svc in problem.services]
    b = [problem.vram_units(svc) for svc in problem.services]
    a_pos = [x for x in a if x > 0]
    b_pos = [x for x in b if x > 0]
    pa = math.ceil(max(a) / min(a_pos)) if a_pos else 0
    pb = math.ceil(max(b) / min(b_pos)) if b_pos else 0
    return pa + pb


def approximation_bound(problem: PlacementProblem) -> float:
    """The guaranteed fraction of optimum: 1 / (1 + P)."""
    return 1.0 / (1.0 + matroid_count(problem))


# ---------------------------------------------------------------------------
# online placement (§3.3): large-scale deployments allocate compute/VRAM
# per-GPU as services arrive, "optimized greedy" in the OpenStack style the
# paper cites [51] — best-fit-decreasing on the bottleneck resource.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OnlinePlacer:
    """Incremental placement: services arrive one at a time (no full R^T);
    each is placed on the feasible server with the highest residual-demand
    match, best-fit on the scarcer of (compute, VRAM).  Used when server
    counts make periodic full SSSP too coarse (§3.3 'online')."""
    problem: PlacementProblem

    def __post_init__(self):
        self.state = PhiState(self.problem)
        self.placed: List[Placement] = []

    def offer(self, svc: str) -> Optional[Placement]:
        """Place one arriving service; returns the placement or None."""
        best, best_score = None, -math.inf
        cu = self.problem.compute_units(svc)
        vu = self.problem.vram_units(svc)
        for server in self.problem.servers:
            cand = (svc, server.sid)
            if not self.state.feasible(cand):
                continue
            gain = self.state.gain(cand)
            c, v = self.state.rem[server.sid]
            # best fit: prefer high phi-gain, tie-break on tightest
            # residual of the bottleneck resource (packs better online)
            slack = min(c - cu, v - vu)
            score = gain - 1e-6 * slack
            if score > best_score:
                best, best_score = cand, score
        if best is None:
            return None
        self.state.add(best)
        self.placed.append(best)
        return best

    def phi(self) -> float:
        return self.state.total()


def online_placement(problem: PlacementProblem,
                     arrival_order: Sequence[str]) -> List[Placement]:
    placer = OnlinePlacer(problem)
    for svc in arrival_order:
        placer.offer(svc)
    return placer.placed


# ---------------------------------------------------------------------------
# cache-policy baselines for Fig. 17b
# ---------------------------------------------------------------------------

def _fill_by_order(problem: PlacementProblem,
                   order: Sequence[str]) -> List[Placement]:
    theta: List[Placement] = []
    for server in problem.servers:
        for svc in order:
            cand = (svc, server.sid)
            if feasible(problem, theta, cand):
                theta.append(cand)
    return theta


def place_lru(problem: PlacementProblem,
              last_used: Mapping[str, float]) -> List[Placement]:
    order = sorted(problem.services, key=lambda s: -last_used.get(s, 0.0))
    return _fill_by_order(problem, order)


def place_lfu(problem: PlacementProblem,
              use_count: Mapping[str, float]) -> List[Placement]:
    order = sorted(problem.services, key=lambda s: -use_count.get(s, 0.0))
    return _fill_by_order(problem, order)


def place_mfu(problem: PlacementProblem,
              use_count: Mapping[str, float]) -> List[Placement]:
    """MFU evicts the most-frequently used -> places least-used first."""
    order = sorted(problem.services, key=lambda s: use_count.get(s, 0.0))
    return _fill_by_order(problem, order)
