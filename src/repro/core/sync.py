"""Information synchronization (§3.4): ring topology, temporal granularity,
and the §5.3.3 error-handling behaviours.

All servers form a ring; every ``interval`` seconds each server transmits
its local digest plus its cached system-wide state to both neighbours
(ring-reduce-like), so information propagates one hop per round in each
direction and the staleness of server m's state at server n is
``ring_distance(n, m) * interval`` plus transmission time.  The handler
consumes these views with their ``sync_age_s`` — that age is exactly the
t_n in Eq. 1.

Error handling:
* ``corrupt(sid)`` — silent data error in one digest; passively corrected
  when the next genuine digest propagates (Fig. 19a);
* ``fail(sid)`` — unresponsive server; neighbours bypass it (the ring
  heals around it) and it is flagged unavailable until ``repair(sid)``.

``ParameterServerSync`` is the drop-in alternative backend (§3.4
"flexibility"): a central aggregator with uniform one-interval staleness.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from .handler import ServerView, ServiceState

DIGEST_BYTES_PER_SERVICE = 64.0
DIGEST_HEADER_BYTES = 256.0


@dataclasses.dataclass
class _CacheEntry:
    view: ServerView
    stamp: float          # local time when this state was *generated*
    corrupted: bool = False


def digest_bytes(num_services: int) -> float:
    return DIGEST_HEADER_BYTES + num_services * DIGEST_BYTES_PER_SERVICE


def sync_round_seconds(num_servers: int, num_services: int,
                       bandwidth_gbps: float) -> float:
    """Wall time for one ring exchange round (Fig. 17d's x-axis model):
    each server ships its full cached table (num_servers digests) to two
    neighbours."""
    payload = 2 * num_servers * digest_bytes(num_services)
    return payload / (bandwidth_gbps * 1e9 / 8) + 0.001


class RingSynchronizer:
    def __init__(self, server_ids: List[int], *, interval_s: float = 1.0,
                 bandwidth_gbps: float = 1.0, num_services: int = 8):
        self.ring = list(server_ids)
        self.interval_s = interval_s
        self.round_cost_s = sync_round_seconds(len(server_ids), num_services,
                                               bandwidth_gbps)
        self._failed: set[int] = set()
        # cache[n][m] = what n believes about m
        self.cache: Dict[int, Dict[int, _CacheEntry]] = {
            sid: {} for sid in server_ids}
        self._last_round = 0.0

    # -- local state publication ------------------------------------------
    def publish_local(self, sid: int, view: ServerView, now: float) -> None:
        if sid in self._failed:
            return
        self.cache[sid][sid] = _CacheEntry(view=view, stamp=now)

    # -- ring exchange ------------------------------------------------------
    def _alive_ring(self) -> List[int]:
        return [s for s in self.ring if s not in self._failed]

    def step(self, now: float) -> None:
        """One bidirectional exchange round (bypassing failed servers)."""
        ring = self._alive_ring()
        n = len(ring)
        if n <= 1:
            return
        snapshot = {sid: dict(self.cache[sid]) for sid in ring}
        for i, sid in enumerate(ring):
            for j in (i - 1, (i + 1) % n):
                peer = ring[j]
                for m, entry in snapshot[peer].items():
                    mine = self.cache[sid].get(m)
                    if mine is None or entry.stamp > mine.stamp:
                        self.cache[sid][m] = entry
        self._last_round = now

    # -- consumption ---------------------------------------------------------
    def views_for(self, sid: int, now: float) -> Dict[int, ServerView]:
        """Peer views as the handler sees them, with sync ages filled in."""
        out: Dict[int, ServerView] = {}
        for m, entry in self.cache[sid].items():
            if m == sid:
                continue
            age = max(0.0, now - entry.stamp) + self.round_cost_s
            view = dataclasses.replace(
                entry.view, sync_age_s=age,
                available=entry.view.available and m not in self._failed)
            out[m] = view
        return out

    def staleness_bound(self, sid: int, peer: int) -> float:
        """Analytic worst-case staleness: ring distance x interval."""
        ring = self._alive_ring()
        if sid not in ring or peer not in ring:
            return float("inf")
        i, j = ring.index(sid), ring.index(peer)
        d = abs(i - j)
        d = min(d, len(ring) - d)
        return d * self.interval_s + self.round_cost_s

    # -- error injection (§5.3.3) ---------------------------------------------
    def corrupt(self, sid: int, *, factor: float = 4.0) -> None:
        """Silently inflate sid's advertised idle goodput everywhere it is
        currently cached (an undetected information error)."""
        for holder in self.cache.values():
            entry = holder.get(sid)
            if entry is None:
                continue
            bad = dataclasses.replace(entry.view, services={
                k: dataclasses.replace(v, theoretical_goodput=
                                       v.theoretical_goodput * factor)
                for k, v in entry.view.services.items()})
            holder[sid] = _CacheEntry(view=bad, stamp=entry.stamp,
                                      corrupted=True)

    def fail(self, sid: int) -> None:
        self._failed.add(sid)

    def repair(self, sid: int) -> None:
        """Rejoin after a restart.  The process lost its in-memory table,
        so its cache comes back EMPTY: the restarted server re-publishes
        its own digest and re-learns peers one ring hop per round — the
        transient where the §5.3.3 staleness bound (not availability
        flags) is what protects the handler."""
        if sid in self._failed:
            self._failed.discard(sid)
            self.cache[sid] = {}

    @property
    def failed(self) -> frozenset:
        return frozenset(self._failed)


class ParameterServerSync:
    """§3.4 flexibility: central parameter-server style sync.  Every server
    sees every other with one-interval staleness; the messager is a single
    point of aggregation."""

    def __init__(self, server_ids: List[int], *, interval_s: float = 1.0):
        self.ids = list(server_ids)
        self.interval_s = interval_s
        self._table: Dict[int, _CacheEntry] = {}
        self._failed: set[int] = set()

    def publish_local(self, sid: int, view: ServerView, now: float) -> None:
        if sid not in self._failed:
            self._table[sid] = _CacheEntry(view=view, stamp=now)

    def step(self, now: float) -> None:  # aggregation is implicit
        return None

    def views_for(self, sid: int, now: float) -> Dict[int, ServerView]:
        out = {}
        for m, entry in self._table.items():
            if m == sid:
                continue
            age = max(0.0, now - entry.stamp) + self.interval_s
            out[m] = dataclasses.replace(
                entry.view, sync_age_s=age,
                available=entry.view.available and m not in self._failed)
        return out

    def corrupt(self, sid: int, **kw) -> None:
        entry = self._table.get(sid)
        if entry:
            self._table[sid] = _CacheEntry(
                view=dataclasses.replace(entry.view), stamp=entry.stamp,
                corrupted=True)

    def fail(self, sid: int) -> None:
        self._failed.add(sid)

    def repair(self, sid: int) -> None:
        # the central table survives a member restart; only the flag lifts
        self._failed.discard(sid)

    @property
    def failed(self) -> frozenset:
        return frozenset(self._failed)
