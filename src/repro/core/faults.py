"""Deterministic fault injection (§5.3.3 exercised as an adversary).

A ``FaultSpec`` is a replayable schedule of failure events against the
control plane and the slot engines: server crash/restart pairs, straggler
slowdowns, silent digest corruption (Fig. 19a), and dropped offload
handoffs.  The spec is pure data — JSON-roundtrippable, generated
deterministically from a seed — so every chaos test, the hypothesis
property suite and ``make bench-chaos`` replay the exact same adversary.

The ``FaultInjector`` walks the schedule against the caller's clock and
dispatches each due event to a target implementing the ``FaultTarget``
surface (``serving/failover.py``'s ``ClusterSupervisor`` for the live
engines; the simulator applies the same spec through its event heap).
Neither side owns recovery policy here: this module only decides WHAT
breaks WHEN, never what the system does about it.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

FAULT_KINDS = ("crash", "restart", "straggle", "corrupt", "drop_offload")


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One scheduled failure.  ``duration_s`` only matters for
    ``straggle`` (slowdown window); ``factor`` is the straggler's
    step-rate divisor or the corruption's goodput inflation; ``count``
    is the number of offload handoffs ``drop_offload`` swallows."""
    at_s: float
    kind: str
    sid: int
    duration_s: float = 0.0
    factor: float = 4.0
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """An ordered, immutable fault schedule.  ``seed`` records how the
    schedule was generated (provenance only — replay never re-rolls)."""
    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events)))

    def for_server(self, sid: int) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.sid == sid)

    def crashed_servers(self) -> Tuple[int, ...]:
        return tuple(sorted({e.sid for e in self.events
                             if e.kind == "crash"}))

    # -- replayable persistence -----------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "events": [dataclasses.asdict(e) for e in self.events]})

    @classmethod
    def from_json(cls, text: str) -> "FaultSpec":
        doc = json.loads(text)
        return cls(events=tuple(FaultEvent(**e) for e in doc["events"]),
                   seed=int(doc.get("seed", 0)))


def random_fault_spec(server_ids: Sequence[int], horizon_s: float, *,
                      seed: int = 0, crashes: int = 1, stragglers: int = 1,
                      corruptions: int = 1, dropped_offloads: int = 1,
                      min_alive: int = 1,
                      restart_after_s: Optional[float] = None) -> FaultSpec:
    """Deterministic seed-driven schedule generator.

    Every crash gets a paired restart (``restart_after_s`` after it, or a
    drawn fraction of the remaining horizon), and at most
    ``len(server_ids) - min_alive`` distinct servers ever crash — the
    adversary may degrade the cluster but never erase it, which is what
    keeps the served-or-verdicted property satisfiable for services
    placed on survivors."""
    if min_alive < 1:
        raise ValueError(f"min_alive must be >= 1, got {min_alive}")
    rng = random.Random(seed)
    ids = list(server_ids)
    events: List[FaultEvent] = []
    crashable = max(0, len(ids) - min_alive)
    victims = rng.sample(ids, min(crashes, crashable))
    for sid in victims:
        t = rng.uniform(0.1, 0.6) * horizon_s
        down = (restart_after_s if restart_after_s is not None
                else rng.uniform(0.1, 0.3) * horizon_s)
        events.append(FaultEvent(at_s=t, kind="crash", sid=sid))
        events.append(FaultEvent(at_s=min(t + down, horizon_s * 0.95),
                                 kind="restart", sid=sid))
    for _ in range(stragglers):
        events.append(FaultEvent(
            at_s=rng.uniform(0.05, 0.8) * horizon_s, kind="straggle",
            sid=rng.choice(ids),
            duration_s=rng.uniform(0.05, 0.2) * horizon_s,
            factor=float(rng.randint(2, 6))))
    for _ in range(corruptions):
        events.append(FaultEvent(
            at_s=rng.uniform(0.05, 0.9) * horizon_s, kind="corrupt",
            sid=rng.choice(ids), factor=rng.uniform(2.0, 8.0)))
    for _ in range(dropped_offloads):
        events.append(FaultEvent(
            at_s=rng.uniform(0.05, 0.9) * horizon_s, kind="drop_offload",
            sid=rng.choice(ids), count=rng.randint(1, 2)))
    return FaultSpec(events=tuple(events), seed=seed)


class FaultTarget(Protocol):
    """What the injector requires of the system under test."""

    def crash(self, ev: FaultEvent, now: float) -> None: ...

    def restart(self, ev: FaultEvent, now: float) -> None: ...

    def straggle(self, ev: FaultEvent, now: float) -> None: ...

    def corrupt(self, ev: FaultEvent, now: float) -> None: ...

    def drop_offload(self, ev: FaultEvent, now: float) -> None: ...


class FaultInjector:
    """Replays a ``FaultSpec`` against a monotonically advancing clock.
    ``drive(now, target)`` fires every not-yet-fired event with
    ``at_s <= now`` in schedule order; replays of the same spec against
    the same clock sequence are bit-identical by construction."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self._queue: List[FaultEvent] = list(spec.events)
        self._idx = 0
        self.fired: List[FaultEvent] = []

    @property
    def pending(self) -> int:
        return len(self._queue) - self._idx

    def next_at(self) -> float:
        """Schedule time of the next unfired event (inf when drained)."""
        if self._idx >= len(self._queue):
            return float("inf")
        return self._queue[self._idx].at_s

    def due(self, now: float) -> List[FaultEvent]:
        out: List[FaultEvent] = []
        while self._idx < len(self._queue) \
                and self._queue[self._idx].at_s <= now:
            out.append(self._queue[self._idx])
            self._idx += 1
        self.fired.extend(out)
        return out

    def drive(self, now: float, target: FaultTarget) -> List[FaultEvent]:
        events = self.due(now)
        for ev in events:
            getattr(target, ev.kind)(ev, now)
        return events
