"""Goodput / SLO accounting (§3.3 semantics).

* latency-sensitive task: satisfied iff it completes within its SLO.
* frequency-sensitive task: partial credit — a stream of F frames with an
  SLO of f* fps served at f fps counts F * min(f, f*) / f* satisfied
  requests (the paper's 120-frame / 60-fps / 30-fps => 60 example).

``GoodputMeter`` also maintains the windowed *actual* goodput p over the
staleness interval [-2t, -t] that Eq. 1 subtracts from p̂.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

from .categories import Request, Sensitivity, ServiceSpec


def latency_satisfied(finish_s: float, deadline_s: float) -> bool:
    return finish_s <= deadline_s


def deadline_expired(deadline_s: float, now: float) -> bool:
    """Shared expiry predicate: True when a request carrying a deadline
    can no longer be satisfied (``deadline_s == 0`` means "no deadline").
    The single home for the ``deadline and now > deadline`` check that
    the handler, every baseline scheduler, the simulator and the
    admission controller all apply before spending any work."""
    return bool(deadline_s) and not latency_satisfied(now, deadline_s)


def frequency_credit(frames: int, achieved_fps: float,
                     slo_fps: float) -> float:
    """F * min(f, f*) / f*  (Eq. 2's y accounting for frequency tasks)."""
    if slo_fps <= 0:
        return float(frames)
    return frames * min(achieved_fps, slo_fps) / slo_fps


@dataclasses.dataclass
class CompletionRecord:
    service: str
    t: float            # completion time
    credit: float       # satisfied-request credit (1 or partial frames)
    violated: bool


class GoodputMeter:
    """Streaming goodput accounting per service + whole system."""

    def __init__(self):
        self._records: Dict[str, List[Tuple[float, float]]] = \
            collections.defaultdict(list)   # service -> [(t, credit)]
        self.total_credit = 0.0
        self.total_offered = 0.0
        self.violations = 0

    # -- recording -------------------------------------------------------
    def offered(self, req: Request) -> None:
        self.total_offered += req.frames

    def complete_latency(self, req: Request, finish_s: float) -> float:
        ok = latency_satisfied(finish_s, req.deadline_s) \
            if req.deadline_s else True
        credit = 1.0 if ok else 0.0
        if not ok:
            self.violations += 1
        self._push(req.service, finish_s, credit)
        return credit

    def complete_frequency(self, req: Request, finish_s: float,
                           achieved_fps: float, slo_fps: float) -> float:
        credit = frequency_credit(req.frames, achieved_fps, slo_fps)
        if credit < req.frames:
            self.violations += 1
        self._push(req.service, finish_s, credit)
        return credit

    def drop(self, req: Request, t: float) -> None:
        self.violations += 1
        self._push(req.service, t, 0.0)

    def _push(self, service: str, t: float, credit: float) -> None:
        """Records are (t, cumulative_credit); completions arrive in event
        order (a min-heap), so times are nondecreasing and windowed sums
        are two bisects over the prefix array — O(log n) instead of the
        O(n) scan that made 16-server/600k-event sims quadratic."""
        recs = self._records[service]
        prev = recs[-1][1] if recs else 0.0
        if recs and t < recs[-1][0]:
            t = recs[-1][0]          # clamp stragglers; keeps monotonicity
        recs.append((t, prev + credit))
        self.total_credit += credit

    # -- queries ------------------------------------------------------------
    def _cum_at(self, recs, t: float) -> float:
        """Cumulative credit of records with time < t."""
        lo, hi = 0, len(recs)
        while lo < hi:
            mid = (lo + hi) // 2
            if recs[mid][0] < t:
                lo = mid + 1
            else:
                hi = mid
        return recs[lo - 1][1] if lo else 0.0

    def goodput(self, service: str, *, window: Tuple[float, float]) -> float:
        """Actual goodput p over [window): credits/sec.  Called by the sync
        layer with window = [now - 2t, now - t] (Eq. 1)."""
        lo, hi = window
        if hi <= lo:
            return 0.0
        recs = self._records.get(service)
        if not recs:
            return 0.0
        total = self._cum_at(recs, hi) - self._cum_at(recs, lo)
        return total / (hi - lo)

    def service_total(self, service: str) -> float:
        recs = self._records.get(service)
        return recs[-1][1] if recs else 0.0

    def system_goodput(self, horizon_s: float) -> float:
        return self.total_credit / horizon_s if horizon_s > 0 else 0.0

    @property
    def fulfillment_ratio(self) -> float:
        if self.total_offered <= 0:
            return 1.0
        return min(1.0, self.total_credit / self.total_offered)
