"""Task-categorized parallelism allocator (§3.1) + adaptive deployment
(§4.1).

Given a service, its SLOs, and the hardware, the allocator decides a
``ParallelPlan`` (MP, BS, MT, MF, DP) by the paper's rules:

* categorize by (latency|frequency) x (<=1 | >1 GPU);
* MP: user-specified or smallest power-of-two whose pooled VRAM fits and
  whose latency meets the SLO (the "DeepSpeed-prescribed" default);
* BS: offline profiling over 2^0..2^9 — largest batch whose latency stays
  within SLO (max throughput under the latency constraint);
* MT: offline profiling over 2^0..2^4 — replication degree bounded by VRAM;
* MF (Eq. 5): inter-frame count bounded by the per-frame latency budget;
  inter_request_count = floor(BS / MF);
* DP (Eq. 4): group count = ceil(fps_requirement / fps_of_one_group).

``mesh_submesh`` maps a plan onto the TPU mesh: DP groups tile the ``data``
axis, MP tiles the ``model`` axis — this is how the paper's technique
becomes a first-class scheduling input for the JAX launcher.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from . import costmodel as cm
from .categories import (CAT_FREQ_MULTI, CAT_FREQ_SINGLE, CAT_LAT_MULTI,
                         CAT_LAT_SINGLE, KV_DTYPE_BY_SENSITIVITY,
                         PARALLEL_SAMPLES_BY_SENSITIVITY,
                         PREFIX_RETENTION_FRACTION,
                         SPECULATE_BY_SENSITIVITY, GPUSpec, Operator,
                         Sensitivity, ServiceSpec, TaskCategory,
                         operators_for)

BS_CANDIDATES = tuple(2 ** i for i in range(10))     # 2^0 .. 2^9  (§4.1)
MT_CANDIDATES = tuple(2 ** i for i in range(5))      # 2^0 .. 2^4  (§4.1)
MAX_MP = 64


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """The allocator's full decision for one service."""
    service: str
    category: TaskCategory
    mp: int = 1          # model-parallel degree (GPUs per replica group)
    bs: int = 1          # batch size
    mt: int = 1          # co-located replication degree on each GPU
    mf: int = 1          # inter-frame count (frequency tasks)
    dp: int = 1          # replica group count (frequency tasks)
    sticky: bool = False  # session-sticky DP routing (stateful archs)
    prefill_chunk: int = 0  # chunked-prefill bucket size in tokens
    #                         (0 = derive from the task category)
    prefix_cache: int = -1  # shared-prefix KV retention knob: -1 = derive
    #                         from the task category (frequency retains
    #                         aggressively, latency bounded), 0 = disabled,
    #                         >0 = max idle cached blocks retained
    kv_dtype: object = -1   # paged-KV precision: -1 = derive from the task
    #                         category (frequency -> "int8", latency ->
    #                         "bf16"), or an explicit "bf16"/"int8" override
    #                         ("bf16" = keep the model's native KV dtype)
    admission: str = "fifo"  # request-admission policy for the serving
    #                          engine: "fifo" = legacy arrival order (never
    #                          sheds; doomed requests rot in queue), "sdf"
    #                          = StrictestDeadlineFirst — order pending
    #                          admissions by deadline slack, shed with
    #                          explicit verdicts (DEADLINE_MISSED /
    #                          CONGESTION / OFFLOAD) and preempt live
    #                          slots by block-table parking under pressure
    speculate: int = -1     # speculative-decoding draft length k: -1 =
    #                         derive from the task category (latency -> k=4
    #                         when a draft model is configured, frequency
    #                         -> 0), 0 = disabled, >0 = explicit k (the
    #                         engine then REQUIRES a draft model)
    n_samples: int = -1     # per-request parallel-sampling cap: -1 =
    #                         derive from the task category (frequency ->
    #                         uncapped up to bs, latency -> 1), 0 =
    #                         uncapped (bs-bounded), >0 = explicit cap on
    #                         a request's n_samples fan-out

    def __post_init__(self):
        for field in ("mp", "bs", "mt", "mf", "dp"):
            v = getattr(self, field)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(
                    f"ParallelPlan.{field} must be a positive int, got "
                    f"{v!r}")
        pc = self.prefill_chunk
        if not isinstance(pc, int) or isinstance(pc, bool) or pc < 0:
            raise ValueError(
                f"ParallelPlan.prefill_chunk must be 0 (category default) "
                f"or a positive token count, got {pc!r}; the serving "
                f"engine additionally requires a multiple of its block "
                f"size")
        px = self.prefix_cache
        if not isinstance(px, int) or isinstance(px, bool) or px < -1:
            raise ValueError(
                f"ParallelPlan.prefix_cache must be -1 (category default), "
                f"0 (disabled) or a positive retention block count, got "
                f"{px!r}")
        kd = self.kv_dtype
        if kd != -1 and kd not in KV_DTYPE_BY_SENSITIVITY.values():
            valid = sorted(set(KV_DTYPE_BY_SENSITIVITY.values()))
            raise ValueError(
                f"ParallelPlan.kv_dtype must be -1 (category default) or "
                f"one of {valid}, got {kd!r}")
        sp = self.speculate
        if not isinstance(sp, int) or isinstance(sp, bool) or sp < -1:
            raise ValueError(
                f"ParallelPlan.speculate must be -1 (category default), 0 "
                f"(disabled) or a positive draft length, got {sp!r}")
        ns = self.n_samples
        if not isinstance(ns, int) or isinstance(ns, bool) or ns < -1:
            raise ValueError(
                f"ParallelPlan.n_samples must be -1 (category default), 0 "
                f"(uncapped) or a positive per-request cap, got {ns!r}")

    @property
    def gpus(self) -> int:
        return self.mp * self.dp

    @property
    def inter_request_count(self) -> int:
        """Eq. 5: concurrent streams multiplexed into one batch."""
        return max(1, self.bs // max(1, self.mf))

    @property
    def max_in_flight(self) -> int:
        """Decode slots per replica runtime: the continuous-batching engine
        keeps at most ``bs`` requests in flight per DP group (the profiled
        batch is the largest the latency SLO tolerates, so it also bounds
        the fused decode batch)."""
        return self.bs

    @property
    def server_slots(self) -> int:
        """Total concurrent decode slots this plan sustains on a server:
        MT co-locates ``mt`` independent runtimes per group (each with its
        own ``bs`` slots) and DP adds ``dp`` replica groups."""
        return self.bs * self.mt * self.dp

    def prefill_chunk_tokens(self, block_size: int = 32) -> int:
        """Chunked-prefill bucket size for the serving engine's
        piggybacked prefill.  Latency-sensitive categories take SMALL
        chunks (prompt work is finely interleaved, so live decode slots
        see minimal added per-step latency); frequency/throughput
        categories take LARGE chunks (fewer, fatter prefill calls — per-
        step stall matters less than aggregate prefill throughput)."""
        if self.prefill_chunk > 0:
            return self.prefill_chunk
        mult = 2 if self.category.sensitivity == Sensitivity.LATENCY else 4
        return mult * block_size

    def prefix_cache_blocks(self, pool_blocks: int,
                            override: Optional[int] = None) -> int:
        """Idle-retention bound for the serving engine's radix prefix
        cache, in arena blocks.  0 disables; otherwise the task category
        decides how aggressively unreferenced-but-cached blocks are
        retained before LRU reclaim: frequency categories (periodic
        repeats of the same prompt prefix) keep the whole reclaimable
        pool, latency categories a bounded fraction."""
        knob = self.prefix_cache if override is None else override
        if knob == 0:
            return 0
        if knob > 0:
            return min(knob, pool_blocks)
        frac = PREFIX_RETENTION_FRACTION[self.category.sensitivity]
        return max(1, int(pool_blocks * frac))

    def resolved_kv_dtype(self) -> str:
        """Paged-KV pool precision for the serving engine's arena.  An
        explicit ``kv_dtype`` wins; -1 derives from the task category:
        frequency tasks (long KV-traffic-bound streams, drift-tolerant
        consumers) quantize blocks to int8 with per-token-per-head scales,
        latency tasks keep the model's native dtype."""
        if self.kv_dtype != -1:
            return self.kv_dtype
        return KV_DTYPE_BY_SENSITIVITY[self.category.sensitivity]

    def resolved_speculate(self, have_draft: bool = True) -> int:
        """Draft length k for speculative decoding.  An explicit
        ``speculate`` wins (and the serving engine rejects k>0 without a
        draft model); -1 derives from the task category — latency tasks
        buy per-request speed (k=4 when a draft model is available),
        frequency tasks buy batch and never speculate."""
        if self.speculate != -1:
            return self.speculate
        if not have_draft:
            return 0
        return SPECULATE_BY_SENSITIVITY[self.category.sensitivity]

    def resolved_n_samples(self) -> int:
        """Per-request parallel-sampling cap.  An explicit ``n_samples``
        wins; -1 derives from the task category — frequency tasks fork
        freely (capped only by ``bs``), latency tasks take the single
        fastest sample.  0 means uncapped (bs-bounded)."""
        if self.n_samples != -1:
            return self.n_samples
        cap = PARALLEL_SAMPLES_BY_SENSITIVITY[self.category.sensitivity]
        return cap if cap else self.bs

    def operators(self):
        ops = set()
        if self.bs > 1:
            ops.add(Operator.BS)
        if self.mt > 1:
            ops.add(Operator.MT)
        if self.mp > 1:
            ops.add(Operator.MP)
        if self.mf > 1:
            ops.add(Operator.MF)
        if self.dp > 1:
            ops.add(Operator.DP)
        return frozenset(ops)


def categorize(svc: ServiceSpec, gpu: GPUSpec, *,
               target_fps: Optional[float] = None) -> TaskCategory:
    """>1 GPU iff the model does not fit a single GPU's VRAM, or a single
    GPU cannot meet the latency SLO at batch 1."""
    multi = cm.min_mp_for_vram(svc, gpu) > 1
    if not multi:
        multi = cm.single_request_latency(svc, gpu) > svc.slo_latency_s
    return TaskCategory(svc.sensitivity, multi)


def _choose_mp(svc: ServiceSpec, gpu: GPUSpec,
               user_mp: Optional[int]) -> int:
    if user_mp is not None:
        return user_mp
    mp = cm.min_mp_for_vram(svc, gpu)
    # grow MP while latency SLO is violated and MP still helps
    while (cm.mp_latency(svc, gpu, mp) > svc.slo_latency_s and mp < MAX_MP):
        nxt = mp * 2
        if cm.mp_latency(svc, gpu, nxt) >= cm.mp_latency(svc, gpu, mp):
            break
        mp = nxt
    return mp


def _profile_bs(svc: ServiceSpec, gpu: GPUSpec, mp: int,
                user_bs: Optional[int]) -> int:
    """Offline profiling (§4.1): largest BS whose batch latency meets the
    latency budget; frequency tasks budget one SLO frame interval."""
    if user_bs is not None:
        return user_bs
    budget = svc.slo_latency_s
    if svc.is_frequency and svc.slo_fps > 0:
        budget = min(budget, max(1.0 / svc.slo_fps, budget * 0.5))
    best = 1
    for bs in BS_CANDIDATES:
        if cm.mp_latency(svc, gpu, mp, batch=bs) <= budget:
            best = bs
    return best


def _profile_mt(svc: ServiceSpec, gpu: GPUSpec, mp: int, bs: int) -> int:
    """Replication degree bounded by VRAM and by the latency budget under
    interference (§4.1's replication profiling)."""
    best = 1
    for mt in MT_CANDIDATES:
        if cm.vram_fraction(svc, gpu, mp) * mt > 1.0:
            break
        lat = cm.effective_latency(svc, gpu, batch=bs, mp=mp, mt=mt)
        if lat <= svc.slo_latency_s:
            best = mt
    return best


def _choose_mf(svc: ServiceSpec, bs: int) -> int:
    """Eq. 5 setup: MF = max inter-frame count tolerated by the per-frame
    latency requirement (grouping delays frames by (mf-1)/fps)."""
    if not svc.is_frequency or svc.slo_fps <= 0:
        return 1
    max_mf = int(svc.slo_latency_s * svc.slo_fps) + 1
    return max(1, min(max_mf, bs))


def _choose_dp(svc: ServiceSpec, gpu: GPUSpec, mp: int, bs: int, mt: int,
               mf: int, target_fps: Optional[float]) -> int:
    """Eq. 4: DP group count = ceil(required fps / fps of one group)."""
    if not svc.is_frequency or svc.slo_fps <= 0:
        return 1
    need = target_fps if target_fps else svc.slo_fps
    one_group = cm.throughput(svc, gpu, batch=bs, mp=mp, mt=mt)
    if one_group <= 0:
        return 1
    return max(1, math.ceil(need / one_group))


def allocate(svc: ServiceSpec, gpu: GPUSpec, *,
             user_mp: Optional[int] = None, user_bs: Optional[int] = None,
             target_fps: Optional[float] = None) -> ParallelPlan:
    """Full §3.1 + §4.1 pipeline for one service."""
    category = categorize(svc, gpu, target_fps=target_fps)
    allowed = operators_for(category)
    mp = _choose_mp(svc, gpu, user_mp) if Operator.MP in allowed else 1
    bs = _profile_bs(svc, gpu, mp, user_bs) if Operator.BS in allowed else 1
    mt = _profile_mt(svc, gpu, mp, bs) if Operator.MT in allowed else 1
    mf = _choose_mf(svc, bs) if Operator.MF in allowed else 1
    dp = (_choose_dp(svc, gpu, mp, bs, mt, mf, target_fps)
          if Operator.DP in allowed else 1)
    # prefill_chunk stays 0: the category-derived mapping in
    # ``prefill_chunk_tokens`` applies at the engine's block size (small
    # chunks for latency tasks, large for frequency/throughput)
    return ParallelPlan(service=svc.name, category=category, mp=mp, bs=bs,
                        mt=mt, mf=mf, dp=dp, sticky=svc.stateful)


def plan_goodput(svc: ServiceSpec, gpu: GPUSpec, plan: ParallelPlan, *,
                 cross_server: bool = False) -> float:
    """Theoretical goodput p̂ (reqs or frames /sec) of one deployed plan."""
    per_group = cm.throughput(svc, gpu, batch=plan.bs, mp=plan.mp,
                              mt=plan.mt, cross_server=cross_server)
    return per_group * plan.dp * plan.mt


# ---------------------------------------------------------------------------
# DP round-robin router (request-level allocation, Fig. 1)
# ---------------------------------------------------------------------------

class DPGroupRouter:
    """Round-robin frames/requests across DP replica groups; sessions of
    stateful archs (SSM/hybrid decode) stick to their group (DESIGN.md §5c)."""

    def __init__(self, plan: ParallelPlan):
        self.plan = plan
        self._next = 0
        self._sessions = {}

    def route(self, session: int = 0) -> int:
        if self.plan.sticky and session:
            if session not in self._sessions:
                self._sessions[session] = self._next
                self._next = (self._next + 1) % self.plan.dp
            return self._sessions[session]
        g = self._next
        self._next = (self._next + 1) % self.plan.dp
        return g

    def release(self, session: int) -> None:
        """Drop a session's group pin.  The serving engine calls this from
        its eviction hook once no request of the session remains queued or
        in flight — without it ``_sessions`` grows forever under a churn
        of short-lived sessions (one entry per session ever seen)."""
        self._sessions.pop(session, None)

    def sessions(self) -> int:
        """Live sticky-session pins (leak observability)."""
        return len(self._sessions)


# ---------------------------------------------------------------------------
# mesh mapping: EPARA plan -> TPU mesh axes (first-class launcher input)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """How a ParallelPlan tiles a (data, model) mesh: ``dp`` replica groups
    along ``data``, ``mp``-way sharding along ``model``."""
    data_parallel: int
    model_parallel: int
    batch_per_group: int

    @property
    def chips(self) -> int:
        return self.data_parallel * self.model_parallel


def mesh_submesh(plan: ParallelPlan) -> MeshPlan:
    return MeshPlan(data_parallel=plan.dp, model_parallel=plan.mp,
                    batch_per_group=plan.bs)
