"""Edge-cloud control plane: servers, device registration (§4.2), the
centralized *messager* (static metadata) and *configurer* (periodic SSSP),
wired to the three temporal granularities of §3.4:

  fine    — request handling, decentralized, on-demand (RequestHandler);
  medium  — information synchronization, ring, every sync_interval;
  coarse  — service placement, centralized, every placement_interval.

Both the live serving engine and the event simulator drive one of these
objects; neither reimplements scheduling logic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from . import costmodel as cm
from .allocator import ParallelPlan, allocate, plan_goodput
from .categories import GPUSpec, Request, ServerSpec, ServiceSpec
from .goodput import GoodputMeter
from .handler import Decision, RequestHandler, ServerView, ServiceState
from .placement import (EPSILON_SERVER, Placement, PlacementProblem, sssp)
from .sync import RingSynchronizer


@dataclasses.dataclass
class EdgeDevice:
    """A registered edge device donating GPU capacity (§4.2): single-GPU
    services only (uncertain lifecycle — no inter-device parallelism)."""
    did: int
    host_server: int
    gpu: GPUSpec = dataclasses.field(
        default_factory=lambda: GPUSpec(name="jetson-like", tflops=20.0,
                                        vram_gb=8.0, mem_bw_gbs=200.0))
    service: Optional[str] = None
    registered_at: float = 0.0
    load_ready_at: float = 0.0


class EdgeCloudControlPlane:
    def __init__(self, servers: Sequence[ServerSpec],
                 services: Mapping[str, ServiceSpec], *,
                 sync_interval_s: float = 1.0,
                 placement_interval_s: float = 60.0,
                 sync_bandwidth_gbps: float = 1.0,
                 max_offload_count: int = 5,
                 peer_staleness_s: Optional[float] = None,
                 seed: int = 0):
        self.servers = list(servers)
        self.services = dict(services)
        self.sync_interval_s = sync_interval_s
        self.placement_interval_s = placement_interval_s
        gpu = self.servers[0].gpu if self.servers else GPUSpec()
        self.gpu = gpu
        # messager: stationary metadata (ids / "addresses")
        self.messager: Dict[int, ServerSpec] = {s.sid: s for s in servers}
        self.plans: Dict[str, ParallelPlan] = {
            name: allocate(svc, gpu) for name, svc in self.services.items()}
        self.sync = RingSynchronizer(
            [s.sid for s in servers], interval_s=sync_interval_s,
            bandwidth_gbps=sync_bandwidth_gbps,
            num_services=max(1, len(services)))
        # degraded-mode guard (§5.3.3): a peer whose digest is older than
        # this bound is treated as DOWN by every handler — a silently
        # crashed server stops refreshing, and its frozen view would
        # otherwise advertise pre-crash idle goodput.  The default gives
        # every publish a full ring traversal plus one spare interval of
        # slack before a peer is written off.
        if peer_staleness_s is None:
            peer_staleness_s = ((len(self.servers) + 1) * sync_interval_s
                                + self.sync.round_cost_s)
        self.peer_staleness_s = peer_staleness_s
        self.handlers: Dict[int, RequestHandler] = {
            s.sid: RequestHandler(s.sid,
                                  max_offload_count=max_offload_count,
                                  staleness_bound_s=peer_staleness_s,
                                  seed=seed)
            for s in servers}
        self.meter = GoodputMeter()
        self.placements: List[Placement] = []
        self.devices: Dict[int, EdgeDevice] = {}
        self._next_device_id = 0
        self._queue_time: Dict[Tuple[int, str], float] = {}

    # -- device management (§4.2) ----------------------------------------
    def register_device(self, host_server: int, now: float,
                        gpu: Optional[GPUSpec] = None) -> EdgeDevice:
        did = self._next_device_id
        self._next_device_id += 1
        dev = EdgeDevice(did=did, host_server=host_server,
                         registered_at=now,
                         **({"gpu": gpu} if gpu else {}))
        self.devices[did] = dev
        return dev

    def assign_device_service(self, did: int, service: str,
                              now: float, *, bw_gbs: float = 1.25) -> float:
        """Ship single-GPU weights to the device; returns ready time."""
        dev = self.devices[did]
        svc = self.services[service]
        if cm.min_mp_for_vram(svc, dev.gpu) > 1:
            raise ValueError(f"{service} needs >1 GPU; devices serve "
                             "single-GPU models only (§4.2)")
        dev.service = service
        dev.load_ready_at = now + cm.model_load_time(svc, bw_gbs)
        return dev.load_ready_at

    def deregister_device(self, did: int) -> None:
        self.devices.pop(did, None)

    # -- placement (coarse granularity) ---------------------------------------
    def build_problem(self, demand: Mapping[Tuple[str, int], float], *,
                      priority_list: Sequence[Placement] = ()) \
            -> PlacementProblem:
        return PlacementProblem(
            services=self.services, plans=self.plans, servers=self.servers,
            demand=dict(demand), period_s=self.placement_interval_s,
            priority_list=tuple(priority_list))

    def run_placement(self, demand: Mapping[Tuple[str, int], float], *,
                      priority_list: Sequence[Placement] = ()) \
            -> List[Placement]:
        problem = self.build_problem(demand, priority_list=priority_list)
        self.placements = sssp(problem)
        return self.placements

    # -- synchronized state (medium granularity) ---------------------------
    def local_view(self, sid: int, now: float) -> ServerView:
        services: Dict[str, ServiceState] = {}
        for svc_name, server_id in self.placements:
            if server_id not in (sid, EPSILON_SERVER):
                continue
            svc = self.services[svc_name]
            plan = self.plans[svc_name]
            cross = server_id == EPSILON_SERVER
            p_hat = plan_goodput(svc, self.gpu, plan, cross_server=cross)
            t = self.sync.round_cost_s
            p_act = self.meter.goodput(
                svc_name, window=(now - 2 * max(t, self.sync_interval_s),
                                  now - max(t, self.sync_interval_s)))
            state = services.setdefault(svc_name, ServiceState())
            state.theoretical_goodput += p_hat
            state.actual_goodput = p_act
            state.queue_time_s = self._queue_time.get((sid, svc_name), 0.0)
            state.cross_server = state.cross_server or cross
        # device-served models (lowest local priority)
        for dev in self.devices.values():
            if dev.host_server == sid and dev.service and \
                    now >= dev.load_ready_at:
                st = services.setdefault(dev.service, ServiceState())
                if st.theoretical_goodput == 0.0:
                    st.on_device = True
                st.theoretical_goodput += cm.throughput(
                    self.services[dev.service], dev.gpu)
        return ServerView(sid=sid, services=services)

    def publish_all(self, now: float) -> None:
        for s in self.servers:
            self.sync.publish_local(s.sid, self.local_view(s.sid, now), now)

    def sync_step(self, now: float) -> None:
        self.sync.step(now)

    def set_queue_time(self, sid: int, service: str, seconds: float) -> None:
        self._queue_time[(sid, service)] = seconds

    # -- failure handling (§5.3.3) ----------------------------------------
    def fail_server(self, sid: int, now: float) -> None:
        """Mark a server crashed: the ring heals around it (exchange
        rounds bypass it) and every peer view flags it unavailable.  The
        sid's queued-time feedback is dropped so a later restart starts
        from a clean signal instead of pre-crash backpressure."""
        self.sync.fail(sid)
        for key in [k for k in self._queue_time if k[0] == sid]:
            del self._queue_time[key]

    def repair_server(self, sid: int, now: float) -> None:
        """Restart rejoin: lift the failure flag (the restarted process
        comes back with an empty sync cache) and re-publish its local
        digest so ring rounds re-propagate a FRESH view — peers stop
        excluding it once the new stamp reaches them."""
        self.sync.repair(sid)
        self.sync.publish_local(sid, self.local_view(sid, now), now)

    @property
    def failed_servers(self) -> frozenset:
        return self.sync.failed

    # -- request handling (fine granularity) ---------------------------------
    def handle(self, req: Request, now: float, at_server: int) -> Decision:
        svc = self.services[req.service]
        local = self.local_view(at_server, now)
        peers = self.sync.views_for(at_server, now)
        if at_server in self.sync.failed:
            # degraded mode: a request can't originate AT a dead server —
            # its local state is gone, so only the offload ladder applies
            local = ServerView(sid=at_server, services={}, available=False)
        return self.handlers[at_server].handle(req, now, svc, local, peers)
