"""EPARA task categories and allocation operators (§3.1, Fig. 5).

A *task* = (request, service).  Tasks are categorized on two axes:

* sensitivity — ``latency`` (non-continuous requests; latency is the sole
  SLO) vs ``frequency`` (continuous/periodic requests; frame-rate is the
  binding SLO, latency a baseline expectation);
* resource — ``<=1 GPU`` vs ``>1 GPU`` (whether the model needs multi-GPU
  collaboration, from VRAM fit and/or latency).

Five allocation operators: BS, MT, MP (service-level), MF, DP
(request-level).  ``OPERATORS_BY_CATEGORY`` reproduces Fig. 5's mapping.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import FrozenSet, Optional, Tuple


class Sensitivity(str, enum.Enum):
    LATENCY = "latency"
    FREQUENCY = "frequency"


class Outcome(str, enum.Enum):
    """The system's ONE verdict vocabulary, shared by the distributed
    handler (§3.2 routing decisions), the serving engine's admission
    controller (``serving/admission.py``) and the simulator's counters —
    so a request's fate is never stringly-typed and a doomed admission can
    be routed by exactly the machinery that routes a fresh arrival.

    Handler routing outcomes (Fig. 6):

    * ``LOCAL`` / ``LOCAL_CROSS`` / ``LOCAL_DEVICE`` — solve here, by the
      §3.2 priority ladder;
    * ``OFFLOAD`` — forward to a peer (also the admission controller's
      "still feasible elsewhere" verdict: positive slack, but the local
      queue would burn it);
    * ``TIMEOUT`` — the SLO already expired before any work started;
    * ``OFFLOAD_EXCEEDED`` / ``INSUFFICIENT`` — bounded hop count / no
      feasible server at all.

    Admission-control verdicts (Icarus-style explicit admission results):

    * ``ADMIT`` — claimed a decode slot;
    * ``DEADLINE_MISSED`` — the slack estimate says the request cannot
      finish ANYWHERE in time (deadline passed or service time alone
      exceeds the remaining budget) — shed it instead of serving dead
      work;
    * ``CONGESTION`` — hard local backpressure (queue beyond the
      congestion bound); the request itself may still be feasible on an
      idle peer, so the handler treats this like a saturated-local signal.

    Fault-tolerance verdict (§5.3.3 recovery, ``core/faults.py``):

    * ``FAILED`` — the request was lost to an injected or real fault
      (crashed server, dropped offload) and could not be replayed on any
      survivor within its retry budget.  The TERMINAL verdict of the
      recovery path: every rid must end served-or-verdicted, so a request
      that exhausts its failover attempts carries this instead of
      silently vanishing with its dead arena.
    """
    LOCAL = "local"                       # solve on this server's GPUs
    LOCAL_CROSS = "local_cross_server"    # cross-server-parallel group
    LOCAL_DEVICE = "local_edge_device"    # registered edge device
    OFFLOAD = "offload"
    TIMEOUT = "timeout"
    OFFLOAD_EXCEEDED = "offload_exceeded"
    INSUFFICIENT = "resource_insufficiency"
    ADMIT = "admit"
    DEADLINE_MISSED = "deadline_missed"
    CONGESTION = "congestion"
    FAILED = "failed"


# Admission verdicts a rejected request can carry (every non-admitted
# request MUST carry exactly one of these — no verdict-less drops).
REJECT_VERDICTS = (Outcome.DEADLINE_MISSED, Outcome.CONGESTION,
                   Outcome.OFFLOAD, Outcome.FAILED)


class Operator(str, enum.Enum):
    BS = "batching"          # service-level: same-service batch
    MT = "multi_task"        # service-level: co-locate services on one GPU
    MP = "model_parallelism"  # service-level: TP/PP across GPUs
    MF = "multi_frame"       # request-level: frames of homogeneous tasks
    DP = "data_parallelism"  # request-level: round-robin replica groups


@dataclasses.dataclass(frozen=True)
class TaskCategory:
    sensitivity: Sensitivity
    multi_gpu: bool

    @property
    def key(self) -> Tuple[str, bool]:
        return (self.sensitivity.value, self.multi_gpu)

    def __str__(self) -> str:
        g = ">1gpu" if self.multi_gpu else "<=1gpu"
        return f"{self.sensitivity.value}/{g}"


CAT_LAT_SINGLE = TaskCategory(Sensitivity.LATENCY, False)
CAT_LAT_MULTI = TaskCategory(Sensitivity.LATENCY, True)
CAT_FREQ_SINGLE = TaskCategory(Sensitivity.FREQUENCY, False)
CAT_FREQ_MULTI = TaskCategory(Sensitivity.FREQUENCY, True)

ALL_CATEGORIES = (CAT_LAT_SINGLE, CAT_LAT_MULTI, CAT_FREQ_SINGLE,
                  CAT_FREQ_MULTI)

# Fig. 5: which operators apply to which category.
OPERATORS_BY_CATEGORY = {
    CAT_LAT_SINGLE.key: frozenset({Operator.BS, Operator.MT}),
    CAT_LAT_MULTI.key: frozenset({Operator.BS, Operator.MT, Operator.MP}),
    CAT_FREQ_SINGLE.key: frozenset({Operator.BS, Operator.MT, Operator.MF}),
    CAT_FREQ_MULTI.key: frozenset({Operator.BS, Operator.MT, Operator.MP,
                                   Operator.MF, Operator.DP}),
}


def operators_for(category: TaskCategory) -> FrozenSet[Operator]:
    return OPERATORS_BY_CATEGORY[category.key]


# Prefix-cache retention by sensitivity (§3.1 applied to KV reuse):
# frequency tasks are periodic repeats of the same system/prompt prefix
# (sensor pipelines, templated LLM calls), so their serving plans retain
# cached prefix blocks aggressively — every reclaimable block stays until
# arena pressure forces LRU eviction.  Latency tasks see mostly one-off
# prompts; holding a large idle cache only delays block reuse, so their
# retention is bounded to a fraction of the pool.
PREFIX_RETENTION_FRACTION = {
    Sensitivity.FREQUENCY: 1.0,
    Sensitivity.LATENCY: 0.25,
}


# Paged-KV precision by sensitivity (§3.1 applied to cache residency):
# frequency tasks run long periodic streams whose decode cost is dominated
# by KV traffic, and their outputs feed rate-driven pipelines that tolerate
# small numeric drift — int8 block quantization (per-token-per-head scales)
# cuts their decode bytes/token roughly 2x and doubles effective arena
# residency.  Latency tasks are one-shot and accuracy-facing; they keep
# the model's native KV dtype ("bf16" = whatever the model computes in).
KV_DTYPE_BY_SENSITIVITY = {
    Sensitivity.FREQUENCY: "int8",
    Sensitivity.LATENCY: "bf16",
}


# Speculative decoding by sensitivity (§3.1 applied to tokens/step):
# latency tasks buy raw per-request speed — a small draft model proposes k
# tokens per round and the fused paged step verifies them in ONE launch,
# multiplying tokens per target launch by up to k+1.  Frequency tasks
# already saturate the device with batch (BS is their operator); running a
# draft model would steal exactly the capacity their frame-rate SLO is
# spending, so they never speculate.
SPECULATE_BY_SENSITIVITY = {
    Sensitivity.LATENCY: 4,
    Sensitivity.FREQUENCY: 0,
}


# Parallel sampling (n>1) by sensitivity: frequency tasks are throughput
# buyers — n-way sampling rides as refcounted forks sharing the prompt's
# paged blocks (COW on divergence), i.e. more tokens/step from machinery
# the batch already paid for (0 = cap at the plan's batch size).  Latency
# tasks want the single fastest answer; forks would only dilute their
# slots.
PARALLEL_SAMPLES_BY_SENSITIVITY = {
    Sensitivity.FREQUENCY: 0,
    Sensitivity.LATENCY: 1,
}


# ---------------------------------------------------------------------------
# services & requests (shared by live engine + simulator)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServiceSpec:
    """A deployable AI service (one model + SLO contract)."""
    name: str
    flops_per_request: float          # fwd FLOPs for one request/frame
    weights_bytes: float              # model weights (placement/load cost)
    vram_bytes: float                 # weights + activations + cache budget
    sensitivity: Sensitivity = Sensitivity.LATENCY
    slo_latency_s: float = 0.5        # latency SLO (both kinds)
    slo_fps: float = 0.0              # frequency SLO (frequency kind only)
    request_bytes: float = 32_768.0   # network payload per request
    arch: Optional[str] = None        # assigned-architecture id, if any
    stateful: bool = False            # SSM/hybrid decode: sticky DP routing
    priority: bool = False            # S1 priority placement list member
    prefix_cacheable: bool = True     # paged KV is a pure function of the
    #                                   prompt tokens (dense/MoE) — the
    #                                   serving engine's prefix-cache gate;
    #                                   the simulator's hit-rate discount
    #                                   applies only when True

    @property
    def is_frequency(self) -> bool:
        return self.sensitivity == Sensitivity.FREQUENCY


@dataclasses.dataclass
class Request:
    """One user request; frequency tasks carry ``frames``/``duration_s``."""
    rid: int
    service: str
    arrival_s: float
    frames: int = 1                  # 1 for latency tasks
    duration_s: float = 0.0          # stream duration for frequency tasks
    prompt_tokens: int = 0           # prompt length (chunked-prefill cost
    #                                  model; 0 = prefill not modeled)
    template: int = 0                # shared-prompt-template id (prefix-
    #                                  cache structure; 0 = one-off prompt)
    deadline_s: float = 0.0          # arrival + SLO (latency tasks)
    path: Tuple[int, ...] = ()       # servers traversed (loop prevention)
    offload_count: int = 0
    session: int = 0                 # sticky-routing key for stateful archs

    def on_path(self, server_id: int) -> bool:
        return server_id in self.path


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str = "tpu-v5e-slice"
    tflops: float = 197.0            # bf16 peak per chip (target hw)
    vram_gb: float = 16.0            # HBM per chip
    mem_bw_gbs: float = 819.0

    @property
    def vram_bytes(self) -> float:
        return self.vram_gb * 1e9

    @property
    def flops(self) -> float:
        return self.tflops * 1e12


# The paper's testbed GPU (Tesla P100 16GB): simulator benchmarks use this
# so goodput ratios are comparable to the paper's; the TPU spec above is
# the dry-run/roofline target hardware.
EDGE_P100 = GPUSpec(name="tesla-p100", tflops=19.0, vram_gb=16.0,
                    mem_bw_gbs=732.0)
EDGE_JETSON = GPUSpec(name="jetson-like", tflops=1.3, vram_gb=4.0,
                      mem_bw_gbs=60.0)


@dataclasses.dataclass
class ServerSpec:
    """An edge server = a co-located group of GPUs (TPU chips)."""
    sid: int
    num_gpus: int = 4
    gpu: GPUSpec = dataclasses.field(default_factory=GPUSpec)
    intra_bw_gbs: float = 50.0       # ICI within the server
    inter_bw_gbs: float = 1.25       # WAN/DCN to peer servers (10 Gb/s)

    @property
    def total_vram(self) -> float:
        return self.num_gpus * self.gpu.vram_bytes
