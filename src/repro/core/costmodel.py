"""Roofline cost model: per-(service, plan, GPU) latency & throughput.

The paper profiles services offline on P100s (§4.1); without that hardware
we derive the same quantities from a two-term roofline (compute vs HBM) per
GPU plus an MP communication penalty, preserving the *ratios* the paper's
claims rest on (DESIGN.md §4).  The allocator's "offline profiling" hooks,
the placement evaluator, and the event simulator all read from here, so
every layer prices work identically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .categories import GPUSpec, ServiceSpec

# batching efficiency: marginal cost of extra batch elements (weights are
# amortized).  eff(1) = 1; large BS approaches the compute-bound floor.
_MP_COMM_OVERHEAD = 0.08           # per extra GPU: collective overhead
_MP_CROSS_SERVER_FACTOR = 6.0      # cross-server MP penalty (slow links)
_MT_INTERFERENCE = 0.06            # per co-located service slowdown
_FLOP_SAT = 4e9                    # work (FLOPs) needed to saturate a GPU:
#                                    below this, achieved FLOP/s scale with
#                                    the batch (occupancy) — this is what
#                                    makes batching worth up to ~10x for
#                                    small models (Fig. 3d's 6.9x)
_LAUNCH_OVERHEAD_S = 3e-4          # per-batch dispatch overhead
_MIN_UTIL = 0.04


def batch_latency(svc: ServiceSpec, gpu: GPUSpec, batch: int) -> float:
    """Roofline latency of a batch: compute at occupancy-scaled throughput
    vs streaming the weights once (batching amortizes both)."""
    work = batch * svc.flops_per_request / gpu.flops
    util = min(1.0, max(_MIN_UTIL,
                        batch * svc.flops_per_request / _FLOP_SAT))
    compute = work / util
    stream = svc.weights_bytes / (gpu.mem_bw_gbs * 1e9)
    return max(compute, stream) + 0.1 * min(compute, stream) \
        + _LAUNCH_OVERHEAD_S


def single_request_latency(svc: ServiceSpec, gpu: GPUSpec) -> float:
    """Batch-1 latency (streams the weights, poor occupancy)."""
    return batch_latency(svc, gpu, 1)


def mp_latency(svc: ServiceSpec, gpu: GPUSpec, mp: int, batch: int = 1, *,
               cross_server: bool = False) -> float:
    """Latency with ``mp``-way model parallelism (TP-like split)."""
    base = batch_latency(svc, gpu, batch)
    overhead = _MP_COMM_OVERHEAD * (mp - 1)
    if cross_server:
        overhead *= _MP_CROSS_SERVER_FACTOR
    return base / mp * (1.0 + overhead)


def throughput(svc: ServiceSpec, gpu: GPUSpec, *, batch: int = 1,
               mp: int = 1, mt: int = 1, cross_server: bool = False) -> float:
    """Requests/sec for one (mp-group) running the service with batch
    ``batch`` and ``mt`` co-located services sharing each GPU."""
    lat = mp_latency(svc, gpu, mp, batch, cross_server=cross_server)
    interference = 1.0 + _MT_INTERFERENCE * (mt - 1)
    return batch / (lat * interference) / mt


def effective_latency(svc: ServiceSpec, gpu: GPUSpec, *, batch: int = 1,
                      mp: int = 1, mt: int = 1, mf: int = 1,
                      cross_server: bool = False) -> float:
    """End-to-end latency a single request sees: queue-free service time
    plus the MF grouping delay (frames wait to fill the inter-frame batch:
    latency rises from 1/fps to mf/fps — §4.1)."""
    lat = mp_latency(svc, gpu, mp, batch, cross_server=cross_server)
    lat *= 1.0 + _MT_INTERFERENCE * (mt - 1)
    if mf > 1 and svc.slo_fps > 0:
        lat += (mf - 1) / svc.slo_fps
    return lat


def min_mp_for_vram(svc: ServiceSpec, gpu: GPUSpec) -> int:
    """Smallest power-of-two GPU count whose pooled VRAM fits the service
    (the paper's >1 GPU criterion)."""
    need = svc.vram_bytes
    mp = 1
    while mp * gpu.vram_bytes < need and mp < 1024:
        mp *= 2
    return mp


def fits_on(svc: ServiceSpec, gpu: GPUSpec, mp: int) -> bool:
    return svc.vram_bytes <= mp * gpu.vram_bytes


def vram_fraction(svc: ServiceSpec, gpu: GPUSpec, mp: int = 1) -> float:
    return svc.vram_bytes / (mp * gpu.vram_bytes)


def model_load_time(svc: ServiceSpec, bw_gbs: float) -> float:
    """Placement cost: time to ship + load weights (Fig. 3f motivation)."""
    return svc.weights_bytes / (bw_gbs * 1e9) + 0.35


def transfer_time(payload_bytes: float, bw_gbs: float) -> float:
    return payload_bytes / (bw_gbs * 1e9) + 0.002  # + fixed RTT
