"""Distributed request handler (§3.2, Fig. 6).

Pure decision logic, shared verbatim by the live serving engine and the
event-driven simulator: given a request, the local server's state, and the
(periodically synchronized, hence STALE) view of peers, decide
LOCAL / OFFLOAD(dest) / TIMEOUT / OFFLOAD_EXCEEDED / INSUFFICIENT.

Key paper semantics implemented here:
* timeout first — SLO-expired requests are dropped immediately;
* local-first, with the priority ladder  pure-local > cross-server-parallel
  local > registered-edge-device local  (§3.2);
* offloading probability  p̃_n / Σ_m p̃_m  with idle goodput
  p̃ = p̂ (theoretical) − p (actual over the stale window [−2t_n, −t_n])
  (Eq. 1);
* destination exclusion when queued compute time exceeds t_n + SLO_r;
* loop-free paths (servers already on the request's path are excluded) and
  a bounded offload count (default 5, §4.1);
* staleness-bound exclusion (§5.3.3 degraded mode): a peer whose view is
  older than ``staleness_bound_s`` is treated as DOWN, not scored on its
  last-known (possibly pre-crash) idle goodput — a silently dead server's
  frozen digest would otherwise look idle, hence maximally attractive.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Iterable, Mapping, Optional, Tuple

# The verdict vocabulary lives in categories (one enum for handler
# decisions, engine admission verdicts and simulator counters);
# ``Outcome`` stays importable from here for the existing call sites.
from .categories import Outcome, Request, ServiceSpec
from .goodput import deadline_expired


@dataclasses.dataclass
class ServiceState:
    """Per-(server, service) scheduling state, as synchronized."""
    theoretical_goodput: float = 0.0   # p̂: deployed plan's capacity
    actual_goodput: float = 0.0        # p: measured over [-2t, -t]
    queue_time_s: float = 0.0          # expected compute time of queue
    cross_server: bool = False         # plan spans servers (lower priority)
    on_device: bool = False            # served by a registered edge device

    @property
    def idle_goodput(self) -> float:
        """p̃ = p̂ − p (Eq. 1), floored at 0."""
        return max(0.0, self.theoretical_goodput - self.actual_goodput)


@dataclasses.dataclass
class ServerView:
    """What one server believes about another (or itself, age 0)."""
    sid: int
    services: Dict[str, ServiceState] = dataclasses.field(default_factory=dict)
    sync_age_s: float = 0.0            # t_n: state information sync delay
    available: bool = True

    def state_of(self, service: str) -> Optional[ServiceState]:
        return self.services.get(service)


@dataclasses.dataclass(frozen=True)
class Decision:
    outcome: Outcome
    destination: Optional[int] = None  # server id for OFFLOAD
    reason: str = ""


class RequestHandler:
    """One per edge server; stateless across requests except for the RNG."""

    def __init__(self, sid: int, *, max_offload_count: int = 5,
                 seed: int = 0,
                 staleness_bound_s: float = float("inf")):
        if staleness_bound_s <= 0:
            raise ValueError(f"staleness_bound_s must be positive, got "
                             f"{staleness_bound_s}")
        self.sid = sid
        self.max_offload_count = max_offload_count
        self.staleness_bound_s = staleness_bound_s
        self._rng = random.Random((seed << 16) ^ sid)

    # -- Fig. 6 ----------------------------------------------------------
    def handle(self, req: Request, now: float, svc: ServiceSpec,
               local: ServerView,
               peers: Mapping[int, ServerView]) -> Decision:
        # 1) timeout
        if deadline_expired(req.deadline_s, now):
            return Decision(Outcome.TIMEOUT, reason="SLO expired")

        # 2) local first, by the §3.2 priority ladder
        local_state = local.state_of(req.service)
        if local_state is not None and self._can_serve(local_state, svc,
                                                       local.sync_age_s):
            if not local_state.cross_server and not local_state.on_device:
                return Decision(Outcome.LOCAL)
            if local_state.cross_server:
                return Decision(Outcome.LOCAL_CROSS)
            return Decision(Outcome.LOCAL_DEVICE)

        # 3) offload
        if req.offload_count >= self.max_offload_count:
            return Decision(Outcome.OFFLOAD_EXCEEDED,
                            reason=f"count={req.offload_count}")
        dest = self._pick_destination(req, svc, peers)
        if dest is not None:
            return Decision(Outcome.OFFLOAD, destination=dest)

        # 4) nothing works
        return Decision(Outcome.INSUFFICIENT)

    # -- helpers -----------------------------------------------------------
    @staticmethod
    def _can_serve(state: ServiceState, svc: ServiceSpec,
                   sync_age_s: float) -> bool:
        if state.idle_goodput > 0:
            return True
        # saturated but queue still inside the SLO budget
        return state.queue_time_s <= max(0.0, svc.slo_latency_s - sync_age_s)

    def _feasible(self, req: Request, svc: ServiceSpec,
                  view: ServerView) -> bool:
        if not view.available or view.sid == self.sid:
            return False
        if view.sync_age_s > self.staleness_bound_s:
            # silent peer: its digest stopped refreshing.  The frozen view
            # still advertises pre-crash idle goodput, so scoring it would
            # ATTRACT traffic to a likely-dead server — exclude instead
            return False
        if req.on_path(view.sid):          # loop prevention
            return False
        state = view.state_of(req.service)
        if state is None:
            return False
        # exclusion: queued compute time beyond t_n + SLO_r (§3.2)
        if state.queue_time_s > view.sync_age_s + svc.slo_latency_s:
            return False
        return state.idle_goodput > 0

    def _pick_destination(self, req: Request, svc: ServiceSpec,
                          peers: Mapping[int, ServerView]) -> Optional[int]:
        candidates: list[Tuple[int, float]] = []
        for view in peers.values():
            if self._feasible(req, svc, view):
                state = view.state_of(req.service)
                candidates.append((view.sid, state.idle_goodput))
        if not candidates:
            return None
        total = sum(w for _, w in candidates)
        if total <= 0:
            return None
        x = self._rng.random() * total
        acc = 0.0
        for sid, w in candidates:
            acc += w
            if x <= acc:
                return sid
        return candidates[-1][0]

    @staticmethod
    def apply_offload(req: Request, origin: int) -> Request:
        """Record the hop on the request (path + count) — the packet-level
        bookkeeping §3.2 uses for loop prevention."""
        return dataclasses.replace(
            req, path=req.path + (origin,),
            offload_count=req.offload_count + 1)
