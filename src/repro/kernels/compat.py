"""JAX version compatibility for Pallas TPU symbols.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases; the kernels target the new spelling and fall back to
the old one so the suite runs on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
