"""Pallas TPU flash-attention BACKWARD kernels.

Standard FlashAttention-2 split:

  dq kernel   — grid (B*Hq, q_blocks, kv_blocks): recompute P per tile from
                (q, k, lse), dS = P*(dP - delta), accumulate dq in VMEM
                scratch over the sequential kv dimension.
  dkdv kernel — grid (B*Hkv, kv_blocks, G*q_blocks): the GQA group and the
                q-block loop are folded into one sequential dimension, so
                dk/dv accumulate contributions from every query head that
                shares the kv head without inter-step races.

Inputs are the fwd residuals: lse (log-sum-exp per row) and
delta = rowsum(dout * out), both computed by the thin jnp wrapper.
Semantics (masks, scaling) match ``ref.flash_attention_bwd_ref`` exactly;
validated in interpret mode by tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

from .ref import NEG_INF

DEFAULT_BLOCK = 128


def _mask_tile(q_lo, k_lo, q_block, k_block, *, causal, window, prefix_len,
               kv_len):
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 1)
    ok = kpos < kv_len
    if causal:
        c = kpos <= qpos
        if window is not None:
            c = jnp.logical_and(c, kpos > qpos - window)
        if prefix_len > 0:
            c = jnp.logical_or(c, kpos < prefix_len)
        ok = jnp.logical_and(ok, c)
    return ok


def _block_visible(q_lo, q_hi, k_lo, k_hi, *, causal, window, prefix_len,
                   kv_len):
    visible = k_lo < kv_len
    if causal:
        visible = jnp.logical_and(visible, k_lo <= q_hi)
        if window is not None:
            in_w = k_hi > q_lo - window
            if prefix_len > 0:
                in_w = jnp.logical_or(in_w, k_lo < prefix_len)
            visible = jnp.logical_and(visible, in_w)
    return visible


def _recompute_p_ds(q, k, v, do, lse, delta, mask, scale):
    """Shared tile math: returns (p, ds) in f32."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, :1]) * mask.astype(jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, :1]) * scale
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref, dq_ref,
               acc_ref, *, scale, causal, window, prefix_len, q_offset,
               kv_len, q_block, k_block, nk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = q_offset + qi * q_block
    k_lo = ki * k_block
    visible = _block_visible(q_lo, q_lo + q_block - 1, k_lo,
                             k_lo + k_block - 1, causal=causal,
                             window=window, prefix_len=prefix_len,
                             kv_len=kv_len)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        mask = _mask_tile(q_lo, k_lo, q_block, k_block, causal=causal,
                          window=window, prefix_len=prefix_len,
                          kv_len=kv_len)
        _, ds = _recompute_p_ds(q, k, v, do, lse_ref[0][:, None],
                                dlt_ref[0][:, None], mask, scale)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref, dk_ref,
                 dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                 prefix_len, q_offset, kv_len, q_block, k_block, nq,
                 nj):
    ki = pl.program_id(1)
    j = pl.program_id(2)          # folded (group, q_block) index
    qi = j % nq

    @pl.when(j == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_lo = q_offset + qi * q_block
    k_lo = ki * k_block
    visible = _block_visible(q_lo, q_lo + q_block - 1, k_lo,
                             k_lo + k_block - 1, causal=causal,
                             window=window, prefix_len=prefix_len,
                             kv_len=kv_len)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        mask = _mask_tile(q_lo, k_lo, q_block, k_block, causal=causal,
                          window=window, prefix_len=prefix_len,
                          kv_len=kv_len)
        p, ds = _recompute_p_ds(q, k, v, do, lse_ref[0][:, None],
                                dlt_ref[0][:, None], mask, scale)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, out, lse, dout, *, causal=True,
                               window: Optional[int] = None,
                               prefix_len: int = 0, q_offset: int = 0,
                               kv_len: Optional[int] = None,
                               softmax_scale=None,
                               q_block: int = DEFAULT_BLOCK,
                               k_block: int = DEFAULT_BLOCK,
                               interpret: bool = False):
    """Same signature/semantics as ``ref.flash_attention_bwd_ref``."""
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    kv_len = Lk if kv_len is None else kv_len
    q_block = min(q_block, max(8, Lq))
    k_block = min(k_block, max(8, Lk))
    Lq_p = -(-Lq // q_block) * q_block
    Lk_p = -(-Lk // k_block) * k_block

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                              # (B, Lq, Hq)

    def to_bh(a, H):  # (B, L, H, D) -> (B*H, Lp, D)
        L, pad = a.shape[1], (Lq_p if a.shape[1] == Lq else Lk_p) - a.shape[1]
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return a.transpose(0, 2, 1, 3).reshape(B * H, a.shape[1], D)

    qt = to_bh(q, Hq)
    kt = to_bh(k, Hkv)
    vt = to_bh(v, Hkv)
    dot_ = to_bh(dout, Hq)
    # padded lse rows must kill p: fill with -NEG_INF (large positive)
    lse_t = jnp.pad(lse, ((0, 0), (0, Lq_p - Lq), (0, 0)),
                    constant_values=-NEG_INF)
    lse_t = lse_t.transpose(0, 2, 1).reshape(B * Hq, Lq_p)
    dlt_t = jnp.pad(delta, ((0, 0), (0, Lq_p - Lq), (0, 0)))
    dlt_t = dlt_t.transpose(0, 2, 1).reshape(B * Hq, Lq_p)

    nq, nk = Lq_p // q_block, Lk_p // k_block
    common = dict(scale=scale, causal=causal, window=window,
                  prefix_len=prefix_len, q_offset=q_offset, kv_len=kv_len,
                  q_block=q_block, k_block=k_block)

    # ---- dq ---------------------------------------------------------------
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, nk=nk, **common),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, qi, ki, g=G: (bh // g, ki, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, qi, ki, g=G: (bh // g, ki, 0)),
            pl.BlockSpec((1, q_block, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, q_block), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, q_block), lambda bh, qi, ki: (bh, qi)),
        ],
        out_specs=pl.BlockSpec((1, q_block, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Lq_p, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((q_block, D), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, dot_, lse_t, dlt_t)

    # ---- dk, dv -------------------------------------------------------------
    nj = G * nq
    dk, dv = pl.pallas_call(
        functools.partial(_dkdv_kernel, nq=nq, nj=nj, **common),
        grid=(B * Hkv, nk, nj),
        in_specs=[
            pl.BlockSpec((1, q_block, D),
                         lambda bkv, ki, j, g=G, n=nq:
                         (bkv * g + j // n, j % n, 0)),
            pl.BlockSpec((1, k_block, D), lambda bkv, ki, j: (bkv, ki, 0)),
            pl.BlockSpec((1, k_block, D), lambda bkv, ki, j: (bkv, ki, 0)),
            pl.BlockSpec((1, q_block, D),
                         lambda bkv, ki, j, g=G, n=nq:
                         (bkv * g + j // n, j % n, 0)),
            pl.BlockSpec((1, q_block),
                         lambda bkv, ki, j, g=G, n=nq:
                         (bkv * g + j // n, j % n)),
            pl.BlockSpec((1, q_block),
                         lambda bkv, ki, j, g=G, n=nq:
                         (bkv * g + j // n, j % n)),
        ],
        out_specs=[
            pl.BlockSpec((1, k_block, D), lambda bkv, ki, j: (bkv, ki, 0)),
            pl.BlockSpec((1, k_block, D), lambda bkv, ki, j: (bkv, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, Lk_p, D), k.dtype),
            jax.ShapeDtypeStruct((B * Hkv, Lk_p, D), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((k_block, D), jnp.float32),
                        pltpu.VMEM((k_block, D), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, dot_, lse_t, dlt_t)

    def from_bh(a, H, L):
        return a.reshape(B, H, -1, D).transpose(0, 2, 1, 3)[:, :L]

    return (from_bh(dq, Hq, Lq), from_bh(dk, Hkv, Lk), from_bh(dv, Hkv, Lk))
