"""Quantized paged-KV block format: int8 values + per-row float scales.

The serving arena's page pools are the decode hot loop's working set, and
decode is memory-bound — bytes/sec IS tokens/sec.  ``QuantPages`` packs a
KV pool as symmetric per-token-per-head int8 with an f32 scale stored as a
sibling array of the same leading (pool, block, row, head) layout, so:

* every block-index operation the arena performs (COW copies, prefix-cache
  sharing, trash-block masking, block-table gathers) applies uniformly to
  values and scales — the scales *travel with the blocks*;
* the paged attention kernels read int8 tiles + an (block, 1) scale column
  and dequantize in-register before QK/PV, never materializing a float
  pool;
* ``QuantPages`` is a registered pytree whose ``.shape``/``.dtype`` proxy
  the value array, so shape-reading call sites (arena classification,
  BlockSpec construction, scan carries, pjit shardings) keep working
  unmodified.

Quantization format (the one both the Pallas kernels and the jnp ref
reproduce bit-for-bit, since de/quantization is the same jnp math):

    scale = max(|x| over the last axis) / 127, floored at ``EPS``
    q     = round(clip(x / scale, -127, 127)) as int8
    x'    = float32(q) * scale
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
EPS = 1e-8          # zero rows quantize to zeros, never divide by zero


@jax.tree_util.register_pytree_node_class
class QuantPages:
    """An int8 array plus per-row (last-axis-reduced) float32 scales.

    ``values.shape == (*lead, D)`` and ``scales.shape == (*lead,)`` — one
    scale per row of the quantized axis.  Shape/dtype attributes proxy the
    value array so existing shape-reading call sites treat a QuantPages
    like the dense pool it replaces.
    """
    __slots__ = ("values", "scales")

    def __init__(self, values, scales):
        self.values = values
        self.scales = scales

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def ndim(self):
        return self.values.ndim

    def tree_flatten(self):
        return (self.values, self.scales), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __repr__(self):
        return (f"QuantPages(values={getattr(self.values, 'shape', None)},"
                f" scales={getattr(self.scales, 'shape', None)})")


def quantize(x):
    """Symmetric per-row int8: (values int8, scales f32) with
    ``scales.shape == x.shape[:-1]``."""
    xf = jnp.asarray(x, jnp.float32)
    scales = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / INT8_MAX, EPS)
    q = jnp.clip(jnp.round(xf / scales[..., None]), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), scales.astype(jnp.float32)


def dequantize(values, scales, dtype=jnp.float32):
    """Inverse of ``quantize`` (up to the rounding loss)."""
    out = values.astype(jnp.float32) * scales[..., None].astype(jnp.float32)
    return out.astype(dtype)


def quantize_like(x, pool):
    """Quantize rows for insertion into ``pool``: a ``QuantPages`` pool gets
    (int8 rows, f32 scales); a dense pool passes through as (rows, None)."""
    if isinstance(pool, QuantPages):
        return quantize(x)
    return x, None


# ---------------------------------------------------------------------------
# tree-aware scan-carry helpers: uniform layer indexing for dense arrays
# (one leaf) and QuantPages (values + scales leaves) inside lax.scan bodies
# ---------------------------------------------------------------------------

def tree_index_layer(tree, i):
    """``dynamic_index_in_dim(leaf, i, 0)`` over every array in ``tree`` —
    a plain array is its own single leaf, so existing dense carries are
    unchanged; a QuantPages carry indexes values and scales together."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        tree)


def tree_update_layer(tree, leaf, i):
    """Inverse of :func:`tree_index_layer`: write ``leaf`` back at layer
    ``i`` of every array in ``tree``."""
    return jax.tree.map(
        lambda a, sub: jax.lax.dynamic_update_index_in_dim(a, sub, i, 0),
        tree, leaf)
