"""Jit-friendly wrappers over the Pallas kernels and their jnp oracles.

Every op takes ``impl``:
  * ``"ref"``               — memory-bounded pure-jnp path (XLA). Default on
                              CPU and for the compiled multi-pod dry-run.
  * ``"pallas"``            — the TPU kernel (deployment target).
  * ``"pallas_interpret"``  — the TPU kernel body interpreted on CPU; used
                              by tests to validate kernels vs the oracles.

``default_impl()`` reads REPRO_KERNEL_IMPL, falling back to "ref" so the
whole framework runs anywhere; on a TPU runtime set REPRO_KERNEL_IMPL=pallas.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from . import ref
from .decode_attention import (chunk_prefill_attention_pallas,
                               decode_attention_pallas, mask_block_tables,
                               paged_gather_ref,
                               paged_chunk_prefill_attention_pallas,
                               paged_chunk_prefill_attention_quant_pallas,
                               paged_decode_attention_pallas,
                               paged_decode_attention_quant_pallas)
from .flash_attention import flash_attention_pallas
from .moe_gemm import grouped_matmul_pallas
from .quant import QuantPages, dequantize
from .ssd_scan import ssd_scan_pallas

VALID_IMPLS = ("ref", "pallas", "pallas_interpret")


def default_impl() -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", "ref")
    if impl not in VALID_IMPLS:
        raise ValueError(f"REPRO_KERNEL_IMPL={impl!r}; want one of {VALID_IMPLS}")
    return impl


import functools

import jax


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_cv(opts, q, k, v):
    out, _ = _flash_fwd(opts, q, k, v)
    return out


def _flash_fwd(opts, q, k, v):
    (causal, window, prefix_len, q_offset, kv_len, scale, impl) = opts
    if impl == "ref":
        out, lse = ref.flash_attention_fwd_ref(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len,
            q_offset=q_offset, kv_len=kv_len, softmax_scale=scale)
    else:
        out, lse = flash_attention_pallas(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len,
            q_offset=q_offset, kv_len=kv_len, softmax_scale=scale,
            return_lse=True, interpret=(impl == "pallas_interpret"))
    return out, (q, k, v, out, lse)


def _flash_bwd(opts, res, dout):
    (causal, window, prefix_len, q_offset, kv_len, scale, impl) = opts
    q, k, v, out, lse = res
    kwargs = dict(causal=causal, window=window, prefix_len=prefix_len,
                  q_offset=q_offset, kv_len=kv_len, softmax_scale=scale)
    if impl == "ref":
        return ref.flash_attention_bwd_ref(q, k, v, out, lse, dout,
                                           **kwargs)
    from .flash_attention_bwd import flash_attention_bwd_pallas
    return flash_attention_bwd_pallas(
        q, k, v, out, lse, dout,
        interpret=(impl == "pallas_interpret"), **kwargs)


_flash_cv.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    prefix_len: int = 0, q_offset: int = 0,
                    kv_len: Optional[int] = None, softmax_scale=None,
                    impl: Optional[str] = None):
    """Flash attention with a recomputing (flash) backward — the O(S^2)
    attention matrix is never materialized in either pass, so training at
    32k context stays within HBM (EXPERIMENTS.md §Dry-run)."""
    impl = impl or default_impl()
    opts = (causal, window, prefix_len, q_offset, kv_len, softmax_scale,
            impl)
    return _flash_cv(opts, q, k, v)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None, softmax_scale=None,
                     impl: Optional[str] = None):
    impl = impl or default_impl()
    if impl == "ref":
        return ref.decode_attention_ref(
            q, k_cache, v_cache, cache_len, window=window,
            softmax_scale=softmax_scale)
    return decode_attention_pallas(
        q, k_cache, v_cache, cache_len, window=window,
        softmax_scale=softmax_scale, interpret=(impl == "pallas_interpret"))


def paged_decode_attention(q, k_pages, v_pages, block_tables, cache_len, *,
                           softmax_scale=None, impl: Optional[str] = None):
    """Decode attention against the serving arena's paged KV layout — the
    families' paged-native decode hot path.

    ``"ref"`` gathers per-slot rows through a length-clipped block table
    (entries past ``cache_len`` route to the trash page, so the CPU
    fallback streams up-to-len rows instead of each slot's full pool) and
    runs the jnp oracle; the Pallas path streams K/V through the table via
    scalar prefetch and skips past-len blocks entirely.

    ``QuantPages`` pools (int8 values + f32 per-row scales) dispatch to the
    quantized kernel variants: the ref path gathers values AND scales
    through the same masked table and dequantizes before the oracle — the
    identical jnp math the in-kernel dequant reproduces.
    """
    impl = impl or default_impl()
    if isinstance(k_pages, QuantPages):
        if impl == "ref":
            bs = k_pages.shape[1]
            trash = k_pages.shape[0] - 1
            bt = mask_block_tables(block_tables, cache_len, bs, trash)
            k = dequantize(paged_gather_ref(k_pages.values, bt),
                           paged_gather_ref(k_pages.scales, bt))
            v = dequantize(paged_gather_ref(v_pages.values, bt),
                           paged_gather_ref(v_pages.scales, bt))
            return ref.decode_attention_ref(q, k, v, cache_len,
                                            softmax_scale=softmax_scale)
        return paged_decode_attention_quant_pallas(
            q, k_pages.values, v_pages.values, k_pages.scales,
            v_pages.scales, block_tables, cache_len,
            softmax_scale=softmax_scale,
            interpret=(impl == "pallas_interpret"))
    if impl == "ref":
        bs, trash = k_pages.shape[1], k_pages.shape[0] - 1
        bt = mask_block_tables(block_tables, cache_len, bs, trash)
        k = paged_gather_ref(k_pages, bt)
        v = paged_gather_ref(v_pages, bt)
        return ref.decode_attention_ref(q, k, v, cache_len,
                                        softmax_scale=softmax_scale)
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, block_tables, cache_len,
        softmax_scale=softmax_scale, interpret=(impl == "pallas_interpret"))


def chunk_attention(q, k_cache, v_cache, start, chunk_len, *,
                    prefix_len: int = 0, softmax_scale=None,
                    impl: Optional[str] = None):
    """Chunked-prefill attention: T query rows at absolute positions
    ``start + i`` against a dense (B, S, Hkv, D) cache that already holds
    the chunk's own K/V (the piggybacked-prefill step writes the cache
    first, then attends).  ``start``/``chunk_len`` may be traced scalars or
    (B,) vectors — unlike ``flash_attention``'s static ``q_offset``, so one
    trace serves every chunk of a bucket size."""
    impl = impl or default_impl()
    if impl == "ref":
        return ref.chunk_attention_ref(q, k_cache, v_cache, start, chunk_len,
                                       prefix_len=prefix_len,
                                       softmax_scale=softmax_scale)
    return chunk_prefill_attention_pallas(
        q, k_cache, v_cache, start, chunk_len, prefix_len=prefix_len,
        softmax_scale=softmax_scale, interpret=(impl == "pallas_interpret"))


def paged_chunk_attention(q, k_pages, v_pages, block_tables, start,
                          chunk_len, *, prefix_len: int = 0,
                          softmax_scale=None, impl: Optional[str] = None):
    """Chunk-prefill attention against the serving arena's paged KV layout
    — the families' paged-native chunked-prefill hot path.

    ``"ref"`` gathers per-slot rows through a length-clipped block table
    (every attendable position sits below ``start + chunk_len``; entries
    past it route to the trash page) and runs the jnp chunk oracle; the
    Pallas path streams K/V through the table via scalar prefetch.
    ``QuantPages`` pools dispatch to the quantized variants, same contract
    as ``paged_decode_attention``.
    """
    impl = impl or default_impl()
    if isinstance(k_pages, QuantPages):
        end = jnp.asarray(start, jnp.int32) + jnp.asarray(chunk_len,
                                                          jnp.int32)
        if impl == "ref":
            bs = k_pages.shape[1]
            trash = k_pages.shape[0] - 1
            bt = mask_block_tables(block_tables, end, bs, trash)
            k = dequantize(paged_gather_ref(k_pages.values, bt),
                           paged_gather_ref(k_pages.scales, bt))
            v = dequantize(paged_gather_ref(v_pages.values, bt),
                           paged_gather_ref(v_pages.scales, bt))
            return ref.chunk_attention_ref(q, k, v, start, chunk_len,
                                           prefix_len=prefix_len,
                                           softmax_scale=softmax_scale)
        return paged_chunk_prefill_attention_quant_pallas(
            q, k_pages.values, v_pages.values, k_pages.scales,
            v_pages.scales, block_tables, start, chunk_len,
            prefix_len=prefix_len, softmax_scale=softmax_scale,
            interpret=(impl == "pallas_interpret"))
    if impl == "ref":
        bs, trash = k_pages.shape[1], k_pages.shape[0] - 1
        end = jnp.asarray(start, jnp.int32) + jnp.asarray(chunk_len,
                                                          jnp.int32)
        bt = mask_block_tables(block_tables, end, bs, trash)
        k = paged_gather_ref(k_pages, bt)
        v = paged_gather_ref(v_pages, bt)
        return ref.chunk_attention_ref(q, k, v, start, chunk_len,
                                       prefix_len=prefix_len,
                                       softmax_scale=softmax_scale)
    return paged_chunk_prefill_attention_pallas(
        q, k_pages, v_pages, block_tables, start, chunk_len,
        prefix_len=prefix_len, softmax_scale=softmax_scale,
        interpret=(impl == "pallas_interpret"))


def paged_verify_attention(q, k_pages, v_pages, block_tables, start,
                           chunk_len, *, prefix_len: int = 0,
                           softmax_scale=None, impl: Optional[str] = None):
    """Speculative-decoding k-token verify against the paged KV layout —
    the SAME kernel path as ``paged_chunk_attention``, restated as the
    verify contract so the engine's one-fused-launch scoring of k draft
    tokens plus the bonus position is pinned down next to the kernels:

    * ``q`` carries T = k+1 rows per slot, the fed tokens
      ``[last_emitted, d_1 .. d_k]`` at absolute positions
      ``start + i``;
    * ``chunk_len`` MUST be a per-slot (B,) vector — T for slots
      speculating this round, 0 for every other row of the fixed-capacity
      batch.  Zero-length rows attend over nothing (their outputs are
      garbage/NaN the verifier masks) and their K/V writes were already
      routed to the trash block by ``paged_insert_rows``;
    * causality inside the chunk is the standard chunk mask: row ``i``
      sees cache positions ``< start + i + 1``, so each draft token is
      scored against exactly the prefix it would have been decoded after
      — which is what makes greedy verify bit-identical to the
      non-speculative oracle, one token per launch.

    No new kernel: verification IS chunked prefill with a per-slot length
    vector (``chunk_prefill_attention_pallas`` /
    ``paged_chunk_prefill_attention_pallas`` already take (B,) lengths
    via scalar prefetch — see ``kernels/decode_attention.py``), so the
    bf16/int8 dispatch and the trash-block masking are inherited
    unchanged."""
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    if chunk_len.ndim != 1:
        raise ValueError(
            f"paged_verify_attention requires a per-slot (B,) chunk_len "
            f"vector (0 = row not speculating), got shape "
            f"{chunk_len.shape}")
    return paged_chunk_attention(q, k_pages, v_pages, block_tables, start,
                                 chunk_len, prefix_len=prefix_len,
                                 softmax_scale=softmax_scale, impl=impl)


def ssd_scan(x, dt, A, B, C, D=None, *, chunk: int = 128,
             initial_state=None, impl: Optional[str] = None):
    impl = impl or default_impl()
    if impl == "ref":
        return ref.ssd_chunked_ref(x, dt, A, B, C, D, chunk=chunk,
                                   initial_state=initial_state)
    return ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                           initial_state=initial_state,
                           interpret=(impl == "pallas_interpret"))


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t, D=None):
    # elementwise-dominated; the jnp path is already optimal on TPU.
    return ref.ssd_decode_step_ref(state, x_t, dt_t, A, B_t, C_t, D)


def grouped_matmul(lhs, rhs, *, impl: Optional[str] = None):
    impl = impl or default_impl()
    if impl == "ref":
        return ref.grouped_matmul_ref(lhs, rhs)
    return grouped_matmul_pallas(lhs, rhs,
                                 interpret=(impl == "pallas_interpret"))
