"""Pure-jnp oracles for every Pallas kernel in this package.

Two tiers per op:

* ``*_exact`` — smallest possible, fully-materialized math. Only used by
  tests as the ground truth.
* ``*_ref``   — memory-bounded (blocked / scanned) jnp implementation with
  identical semantics.  This is what the model zoo runs through XLA on the
  dry-run path (full attention at 32k+ cannot materialize (L, L) scores),
  and what the Pallas kernels are validated against bit-for-bit modulo
  dtype.

Shapes follow the convention:
  q        : (B, Lq, Hq, D)
  k, v     : (B, Lk, Hkv, D)       Hq % Hkv == 0 (GQA)
  output   : (B, Lq, Hq, D)
Masking semantics (shared by exact/ref/pallas):
  A key at absolute position kp is visible to a query at absolute position
  qp iff
      (kp < prefix_len)                                  # bidirectional prefix
   or (not causal) and (kp < kv_len)                     # full attention
   or (causal and kp <= qp and (window is None or kp > qp - window))
  and always kp < kv_len (the valid-cache mask for decode).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _visibility(qpos, kpos, *, causal: bool, window: Optional[int],
                prefix_len: int, kv_len) -> jnp.ndarray:
    """Boolean (Lq, Lk) visibility mask per the module docstring."""
    qpos = qpos[:, None]
    kpos = kpos[None, :]
    valid = kpos < kv_len
    if causal:
        ok = kpos <= qpos
        if window is not None:
            ok = ok & (kpos > qpos - window)
    else:
        ok = jnp.ones_like(valid)
    if prefix_len:
        ok = ok | (kpos < prefix_len)
    return ok & valid


def mha_exact(q, k, v, *, causal=True, window=None, prefix_len=0,
              q_offset=0, kv_len=None, softmax_scale=None):
    """Fully materialized attention. Test oracle only (small shapes)."""
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    kv_len = Lk if kv_len is None else kv_len
    qg = q.reshape(B, Lq, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("blhgd,bshd->bhgls", qg, kf) * scale
    qpos = q_offset + jnp.arange(Lq)
    kpos = jnp.arange(Lk)
    mask = _visibility(qpos, kpos, causal=causal, window=window,
                       prefix_len=prefix_len, kv_len=kv_len)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgls,bshd->blhgd", p, vf)
    # fully-masked rows are defined as 0 (matches the flash recurrence,
    # where l stays 0); softmax alone would emit a uniform average
    any_visible = mask.any(axis=-1)[None, :, None, None, None]
    out = jnp.where(any_visible, out, 0.0)
    return out.reshape(B, Lq, Hq, D).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, prefix_len=0,
                        q_offset=0, kv_len=None, softmax_scale=None,
                        q_chunk=512, k_chunk=512):
    """Blocked online-softmax attention, O(chunk^2) transient memory.

    Numerically the standard two-pass-free flash recurrence:
      m' = max(m, rowmax(s));  l' = l * e^{m-m'} + rowsum(e^{s-m'})
      acc' = acc * e^{m-m'} + e^{s-m'} @ V
    """
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    kv_len = Lk if kv_len is None else kv_len

    q_chunk = min(q_chunk, Lq)
    k_chunk = min(k_chunk, Lk)
    # pad to multiples
    Lq_p = -(-Lq // q_chunk) * q_chunk
    Lk_p = -(-Lk // k_chunk) * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, Lq_p - Lq), (0, 0), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))

    nq, nk = Lq_p // q_chunk, Lk_p // k_chunk
    # keep blocks in the input dtype: upcasting (B, L, d)-sized tensors to
    # f32 before the blocked reshapes doubles every activation reshard
    # collective on the production mesh (EXPERIMENTS.md SPerf); einsums
    # below accumulate in f32 via preferred_element_type instead
    qb = qp.reshape(B, nq, q_chunk, Hkv, G, D)
    kb = kp_.reshape(B, nk, k_chunk, Hkv, D)
    vb = vp.reshape(B, nk, k_chunk, Hkv, D)

    def per_batch(qb_b, kb_b, vb_b):
        def q_scan(_, inputs):
            qi, q_tile = inputs
            return None, q_block_fn(qi, q_tile, kb_b, vb_b)

        _, outs = jax.lax.scan(q_scan, None, (jnp.arange(nq), qb_b))
        return outs  # (nq, Hkv, G, q_chunk, D)

    def q_block_fn(qi, q_tile, kb_b, vb_b):
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_tile, v_tile = inputs
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("lhgd,shd->hgls", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            mask = _visibility(qpos, kpos, causal=causal, window=window,
                               prefix_len=prefix_len, kv_len=kv_len)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # mask p explicitly: a fully-masked block has m == NEG_INF and
            # exp(s - m) == 1 for every (masked!) entry otherwise
            p = jnp.exp(s - m_new[..., None]) * mask[None, None]
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "hgls,shd->hgld", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((Hkv, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb_b, vb_b))
        return acc / jnp.maximum(l, 1e-37)[..., None]

    outs = jax.vmap(per_batch)(qb, kb, vb)  # (B, nq, Hkv, G, q_chunk, D)
    out = outs.transpose(0, 1, 4, 2, 3, 5).reshape(B, Lq_p, Hq, D)
    return out[:, :Lq].astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len, *, window=None,
                         softmax_scale=None):
    """Single-token decode attention against a (B, S, Hkv, D) cache.

    ``cache_len`` is the number of valid entries (scalar or (B,) int array);
    the new token attends to positions [0, cache_len) (optionally only the
    last ``window`` of them).  q: (B, Hq, D) -> out (B, Hq, D).
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kf) * scale
    kpos = jnp.arange(S)[None]          # (1, S)
    valid = kpos < cache_len[:, None]
    if window is not None:
        valid = valid & (kpos >= cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    out = jnp.where(valid.any(-1)[:, None, None, None], out, 0.0)
    return out.reshape(B, Hq, D).astype(q.dtype)


def chunk_attention_ref(q, k_cache, v_cache, start, chunk_len, *,
                        prefix_len=0, softmax_scale=None):
    """Chunked-prefill attention: a block of T query positions against a
    (B, S, Hkv, D) cache that already holds the earlier context AND the
    chunk's own freshly written K/V.

    ``start`` (scalar or (B,)) counts cache tokens present BEFORE the
    chunk; query row i sits at absolute position ``start + i``.  Only the
    first ``chunk_len`` rows are real (the chunk is right-padded to a
    static bucket size); a key at position kp is visible to query row i iff
        kp <= start + i  and  i < chunk_len        # causal over the cache
     or kp < prefix_len                            # bidirectional prefix
    and always kp < start + chunk_len (padding rows past the chunk hold
    garbage).  Rows i >= chunk_len produce zeros — callers discard them.

    q: (B, T, Hq, D) -> (B, T, Hq, D).  Transients are (T, S): bounded by
    the serving arena's per-slot budget, so this stays materialized (it is
    the CPU/XLA twin of ``chunk_prefill_attention_pallas``).
    """
    B, T, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.full((B,), start)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    if chunk_len.ndim == 0:
        chunk_len = jnp.full((B,), chunk_len)
    qpos = start[:, None] + jnp.arange(T)[None]          # (B, T)
    kpos = jnp.arange(S)[None, None]                     # (1, 1, S)
    ok = kpos <= qpos[..., None]
    if prefix_len:
        ok = ok | (kpos < prefix_len)
    ok = ok & (kpos < (start + chunk_len)[:, None, None])
    ok = ok & (jnp.arange(T)[None, :, None] < chunk_len[:, None, None])
    qg = q.reshape(B, T, Hkv, G, D).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("blhgd,bshd->bhgls", qg, kf) * scale
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgls,bshd->blhgd", p, vf)
    any_visible = ok.any(axis=-1)[:, :, None, None, None]
    out = jnp.where(any_visible, out, 0.0)
    return out.reshape(B, T, Hq, D).astype(q.dtype)


def flash_attention_fwd_ref(q, k, v, *, causal=True, window=None,
                            prefix_len=0, q_offset=0, kv_len=None,
                            softmax_scale=None, q_chunk=512, k_chunk=512):
    """Like ``flash_attention_ref`` but also returns the log-sum-exp
    (B, Lq, Hq) needed by the recomputing backward."""
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    kv_len = Lk if kv_len is None else kv_len
    q_chunk = min(q_chunk, Lq)
    k_chunk = min(k_chunk, Lk)
    Lq_p = -(-Lq // q_chunk) * q_chunk
    Lk_p = -(-Lk // k_chunk) * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, Lq_p - Lq), (0, 0), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    nq, nk = Lq_p // q_chunk, Lk_p // k_chunk
    # keep blocks in the input dtype: upcasting (B, L, d)-sized tensors to
    # f32 before the blocked reshapes doubles every activation reshard
    # collective on the production mesh (EXPERIMENTS.md SPerf); einsums
    # below accumulate in f32 via preferred_element_type instead
    qb = qp.reshape(B, nq, q_chunk, Hkv, G, D)
    kb = kp_.reshape(B, nk, k_chunk, Hkv, D)
    vb = vp.reshape(B, nk, k_chunk, Hkv, D)

    def q_block_fn(qi, q_tile, kb_b, vb_b):
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_tile, v_tile = inputs
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("lhgd,shd->hgls", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            mask = _visibility(qpos, kpos, causal=causal, window=window,
                               prefix_len=prefix_len, kv_len=kv_len)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # mask p explicitly: a fully-masked block has m == NEG_INF and
            # exp(s - m) == 1 for every (masked!) entry otherwise
            p = jnp.exp(s - m_new[..., None]) * mask[None, None]
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "hgls,shd->hgld", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((Hkv, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb_b, vb_b))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-37)), -NEG_INF)
        return out, lse

    def per_batch(qb_b, kb_b, vb_b):
        def q_scan(_, inputs):
            qi, q_tile = inputs
            return None, q_block_fn(qi, q_tile, kb_b, vb_b)

        _, (outs, lses) = jax.lax.scan(q_scan, None, (jnp.arange(nq), qb_b))
        return outs, lses

    outs, lses = jax.vmap(per_batch)(qb, kb, vb)
    out = outs.transpose(0, 1, 4, 2, 3, 5).reshape(B, Lq_p, Hq, D)
    lse = lses.transpose(0, 1, 4, 2, 3).reshape(B, Lq_p, Hq)
    return out[:, :Lq].astype(q.dtype), lse[:, :Lq]


def flash_attention_bwd_ref(q, k, v, out, lse, dout, *, causal=True,
                            window=None, prefix_len=0, q_offset=0,
                            kv_len=None, softmax_scale=None, q_chunk=512,
                            k_chunk=512):
    """Recomputing flash backward: O(chunk^2) transients, never the full
    attention matrix.  Standard dS = P * (dP - delta) algebra."""
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    kv_len = Lk if kv_len is None else kv_len
    q_chunk = min(q_chunk, Lq)
    k_chunk = min(k_chunk, Lk)
    Lq_p = -(-Lq // q_chunk) * q_chunk
    Lk_p = -(-Lk // k_chunk) * k_chunk

    def padq(a, fill=0.0):
        return jnp.pad(a, ((0, 0), (0, Lq_p - Lq)) +
                       ((0, 0),) * (a.ndim - 2), constant_values=fill)

    def padk(a):
        return jnp.pad(a, ((0, 0), (0, Lk_p - Lk)) +
                       ((0, 0),) * (a.ndim - 2))

    nq, nk = Lq_p // q_chunk, Lk_p // k_chunk
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                              # (B, Lq, Hq)
    qb = padq(q).reshape(B, nq, q_chunk, Hkv, G, D)
    dob = padq(dout).reshape(B, nq, q_chunk, Hkv, G, D)
    # padded lse must kill p: use -NEG_INF (large positive)
    lseb = padq(lse, fill=-NEG_INF).reshape(B, nq, q_chunk, Hkv, G)
    deltab = padq(delta).reshape(B, nq, q_chunk, Hkv, G)
    kb = padk(k).reshape(B, nk, k_chunk, Hkv, D)
    vb = padk(v).reshape(B, nk, k_chunk, Hkv, D)

    def block_grads(qi, ki, q_tile, do_tile, lse_tile, dlt_tile, k_tile,
                    v_tile):
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        kpos = ki * k_chunk + jnp.arange(k_chunk)
        f32 = jnp.float32
        s = jnp.einsum("lhgd,shd->hgls", q_tile, k_tile,
                       preferred_element_type=f32) * scale
        mask = _visibility(qpos, kpos, causal=causal, window=window,
                           prefix_len=prefix_len, kv_len=kv_len)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.exp(s - lse_tile.transpose(1, 2, 0)[..., None])  # (h,g,l,s)
        dp = jnp.einsum("lhgd,shd->hgls", do_tile, v_tile,
                        preferred_element_type=f32)
        ds = p * (dp - dlt_tile.transpose(1, 2, 0)[..., None]) * scale
        dsl = ds.astype(k_tile.dtype)
        dq_b = jnp.einsum("hgls,shd->lhgd", dsl, k_tile,
                          preferred_element_type=f32)
        dk_b = jnp.einsum("hgls,lhgd->shd", dsl, q_tile,
                          preferred_element_type=f32)
        dv_b = jnp.einsum("hgls,lhgd->shd", p.astype(do_tile.dtype),
                          do_tile, preferred_element_type=f32)
        return dq_b, dk_b, dv_b

    def per_batch(qb_b, dob_b, lseb_b, dltb_b, kb_b, vb_b):
        def q_scan(carry, qin):
            dk_acc, dv_acc = carry
            qi, q_tile, do_tile, lse_tile, dlt_tile = qin

            def k_scan(kcarry, kin):
                dq_acc = kcarry
                ki, k_tile, v_tile = kin
                dq_b, dk_b, dv_b = block_grads(
                    qi, ki, q_tile, do_tile, lse_tile, dlt_tile, k_tile,
                    v_tile)
                return dq_acc + dq_b, (dk_b, dv_b)

            dq0 = jnp.zeros((q_chunk, Hkv, G, D), jnp.float32)
            dq_tile, (dk_parts, dv_parts) = jax.lax.scan(
                k_scan, dq0, (jnp.arange(nk), kb_b, vb_b))
            dk_acc = dk_acc + dk_parts.reshape(Lk_p, Hkv, D)
            dv_acc = dv_acc + dv_parts.reshape(Lk_p, Hkv, D)
            return (dk_acc, dv_acc), dq_tile

        dk0 = jnp.zeros((Lk_p, Hkv, D), jnp.float32)
        dv0 = jnp.zeros((Lk_p, Hkv, D), jnp.float32)
        (dk_acc, dv_acc), dq_tiles = jax.lax.scan(
            q_scan, (dk0, dv0),
            (jnp.arange(nq), qb_b, dob_b, lseb_b, dltb_b))
        return dq_tiles, dk_acc, dv_acc

    dq, dk, dv = jax.vmap(per_batch)(qb, dob, lseb, deltab, kb, vb)
    dq = dq.reshape(B, Lq_p, Hq, D)[:, :Lq].astype(q.dtype)
    dk = dk.reshape(B, Lk_p, Hkv, D)[:, :Lk].astype(k.dtype)
    dv = dv.reshape(B, Lk_p, Hkv, D)[:, :Lk].astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality), chunked
# ---------------------------------------------------------------------------

def ssd_exact(x, dt, A, B, C, D=None, *, initial_state=None):
    """Naive sequential recurrence. Test oracle only.

    x : (Bb, L, H, P)   dt : (Bb, L, H)   A : (H,) (negative)
    B, C : (Bb, L, G, N)  heads grouped H//G per group.
    Returns y (Bb, L, H, P) and final state (Bb, H, P, N).
    """
    Bb, L, H, P = x.shape
    _, _, G, N = B.shape
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)  # (Bb, L, H, N)
    Ch = jnp.repeat(C, rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, None, :])  # (Bb, L, H)

    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h, t):
        xt, dtt, dAt = xf[:, t], dtf[:, t], dA[:, t]
        Bt, Ct = Bh[:, t].astype(jnp.float32), Ch[:, t].astype(jnp.float32)
        h = h * dAt[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", xt, Bt, dtt)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(L))
    y = ys.transpose(1, 0, 2, 3)  # (Bb, L, H, P)
    if D is not None:
        y = y + xf * D[None, None, :, None]
    return y.astype(x.dtype), h


def ssd_chunked_ref(x, dt, A, B, C, D=None, *, chunk=128, initial_state=None):
    """Chunked SSD: intra-chunk quadratic part + inter-chunk state recurrence.

    Memory-bounded in L (transients are (chunk, chunk)); this is the jnp
    twin of the Pallas ``ssd_scan`` kernel and the model-zoo prefill path.
    """
    Bb, L, H, P = x.shape
    _, _, G, N = B.shape
    rep = H // G
    Q = min(chunk, L)
    Lp = -(-L // Q) * Q
    pad = Lp - L

    def padt(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xf = padt(x).astype(jnp.float32)
    dtf = padt(dt).astype(jnp.float32)
    # padded steps must be identity: dt=0 => dA=1? exp(0*A)=1 keeps state, and
    # contributes 0 input. dt=0 gives exactly that.
    Bh = jnp.repeat(padt(B), rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(padt(C), rep, axis=2).astype(jnp.float32)
    nC = Lp // Q
    xc = xf.reshape(Bb, nC, Q, H, P)
    dtc = dtf.reshape(Bb, nC, Q, H)
    Bc = Bh.reshape(Bb, nC, Q, H, N)
    Cc = Ch.reshape(Bb, nC, Q, H, N)

    logdA = dtc * A[None, None, None, :]           # (Bb, nC, Q, H), <= 0
    cum = jnp.cumsum(logdA, axis=2)                # inclusive cumsum

    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def chunk_step(h, c):
        xq, dtq, Bq, Cq = xc[:, c], dtc[:, c], Bc[:, c], Cc[:, c]
        cq = cum[:, c]                              # (Bb, Q, H)
        # intra-chunk: M[t,s] = exp(cum_t - cum_s) * (C_t . B_s) * dt_s, s<=t
        decay = jnp.exp(cq[:, :, None] - cq[:, None])        # (Bb, t, s, H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("bthn,bshn->btsh", Cq, Bq)
        M = decay * cb * dtq[:, None]                         # (Bb, t, s, H)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bthn,bhpn,bth->bthp", Cq, h, jnp.exp(cq))
        # chunk state: S = sum_s exp(cum_last - cum_s) dt_s x_s B_s^T
        last = cq[:, -1][:, None]                             # (Bb, 1, H)
        w = jnp.exp(last - cq) * dtq                          # (Bb, Q, H)
        S = jnp.einsum("bshp,bshn,bsh->bhpn", xq, Bq, w)
        h_new = h * jnp.exp(last[:, 0])[..., None, None] + S
        return h_new, y_intra + y_inter

    h, ys = jax.lax.scan(chunk_step, h0, jnp.arange(nC))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, Lp, H, P)[:, :L]
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h


def ssd_decode_step_ref(state, x_t, dt_t, A, B_t, C_t, D=None):
    """One recurrent SSD step.

    state : (Bb, H, P, N);  x_t : (Bb, H, P);  dt_t : (Bb, H);
    B_t, C_t : (Bb, G, N).  Returns (y_t (Bb, H, P), new_state).
    """
    Bb, H, P, N = state.shape
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)   # (Bb, H, N)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])
    state = state.astype(jnp.float32)
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xf, Bh, dtf)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    if D is not None:
        y = y + xf * D[None, :, None]
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# grouped (per-expert) matmul
# ---------------------------------------------------------------------------

def grouped_matmul_ref(lhs, rhs):
    """(E, C, K) @ (E, K, N) -> (E, C, N), fp32 accumulate."""
    out = jnp.einsum("eck,ekn->ecn", lhs.astype(jnp.float32),
                     rhs.astype(jnp.float32))
    return out.astype(lhs.dtype)
