"""Pallas TPU flash-attention (prefill/training) kernel.

TPU-native adaptation: online-softmax over KV blocks streamed through VMEM,
MXU-aligned (128x128 default) tiles, grid = (batch*q_heads, q_blocks,
kv_blocks) with the kv dimension sequential ("arbitrary") carrying the
(m, l, acc) running statistics in VMEM scratch.  GQA is handled by index
mapping: the kv operand is indexed by ``bh // group`` so kv tiles are
fetched from the shared kv head.

Supports: causal, sliding-window, bidirectional prefix (prefix-LM), valid
kv-length masking, and a query offset (for chunked prefill) — the same
semantics as ``ref.mha_exact``.

Validated on CPU with ``interpret=True`` against ``ref.py``; compiled for
TPU as the deployment target.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

from .ref import NEG_INF

DEFAULT_Q_BLOCK = 128
DEFAULT_K_BLOCK = 128
_LANES = 128  # TPU lane width for the (m, l) statistic tiles


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                  acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  prefix_len: int, q_offset: int, kv_len: int,
                  q_block: int, k_block: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level visibility: skip fully-masked kv blocks (this is what makes
    # the kernel sub-quadratic for sliding-window attention).
    q_lo = q_offset + qi * q_block          # first query position in tile
    q_hi = q_lo + q_block - 1
    k_lo = ki * k_block
    k_hi = k_lo + k_block - 1
    visible = k_lo < kv_len
    if causal:
        visible = jnp.logical_and(visible, k_lo <= q_hi)
        if window is not None:
            in_window = k_hi > q_lo - window
            if prefix_len > 0:
                in_window = jnp.logical_or(in_window, k_lo < prefix_len)
            visible = jnp.logical_and(visible, in_window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 1)
        ok = kpos < kv_len
        if causal:
            c = kpos <= qpos
            if window is not None:
                c = jnp.logical_and(c, kpos > qpos - window)
            if prefix_len > 0:
                c = jnp.logical_or(c, kpos < prefix_len)
            ok = jnp.logical_and(ok, c)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                       # (q_block, LANES), cols equal
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # mask p: fully-masked rows would otherwise get exp(0) == 1
        p = jnp.exp(s - m_new[:, :1]) * ok.astype(jnp.float32)
        l_ref[...] = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)
        # log-sum-exp residual for the recomputing backward; fully-masked
        # rows get -NEG_INF (large positive) so exp(s - lse) == 0 there
        m = m_ref[...][:, :1]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-37)),
                        -NEG_INF)
        lse_ref[0] = lse[:, 0]


def flash_attention_pallas(q, k, v, *, causal=True, window=None, prefix_len=0,
                           q_offset=0, kv_len=None, softmax_scale=None,
                           q_block=DEFAULT_Q_BLOCK, k_block=DEFAULT_K_BLOCK,
                           return_lse=False, interpret=False):
    """q: (B, Lq, Hq, D); k, v: (B, Lk, Hkv, D) -> (B, Lq, Hq, D)
    [, lse (B, Lq, Hq) when return_lse]."""
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    kv_len = Lk if kv_len is None else kv_len

    q_block = min(q_block, max(8, Lq))
    k_block = min(k_block, max(8, Lk))
    Lq_p = -(-Lq // q_block) * q_block
    Lk_p = -(-Lk // k_block) * k_block

    qt = jnp.pad(q, ((0, 0), (0, Lq_p - Lq), (0, 0), (0, 0)))
    kt = jnp.pad(k, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    vt = jnp.pad(v, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    # (B, L, H, D) -> (B*H, L, D)
    qt = qt.transpose(0, 2, 1, 3).reshape(B * Hq, Lq_p, D)
    kt = kt.transpose(0, 2, 1, 3).reshape(B * Hkv, Lk_p, D)
    vt = vt.transpose(0, 2, 1, 3).reshape(B * Hkv, Lk_p, D)

    nq = Lq_p // q_block
    nk = Lk_p // k_block
    grid = (B * Hq, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        prefix_len=prefix_len, q_offset=q_offset, kv_len=kv_len,
        q_block=q_block, k_block=k_block, nk=nk)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, qi, ki, group=group: (bh // group, ki, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, qi, ki, group=group: (bh // group, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, D),
                         lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, q_block), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Lq_p, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, Lq_p), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_block, _LANES), jnp.float32),   # m
            pltpu.VMEM((q_block, _LANES), jnp.float32),   # l
            pltpu.VMEM((q_block, D), jnp.float32),        # acc
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)

    out = out.reshape(B, Hq, Lq_p, D).transpose(0, 2, 1, 3)[:, :Lq]
    if return_lse:
        lse = lse.reshape(B, Hq, Lq_p).transpose(0, 2, 1)[:, :Lq]
        return out, lse
    return out
