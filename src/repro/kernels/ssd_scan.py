"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation of the SSD ("state-space duality") algorithm: the sequence is
split into chunks of Q tokens; within a chunk the output is a masked
(Q x Q) matmul (MXU-friendly), across chunks a (P x N) state is carried
sequentially in VMEM scratch.  Grid = (batch*heads, chunks) with the chunk
dimension "arbitrary" (sequential) so the state scratch implements the
recurrence; batch*heads is embarrassingly parallel.

Inputs are laid out per (b, h):
  x  : (BH, L, P)      head channels
  dt : (BH, L, 1)      softplus-discretized step
  B  : (BH, L, N)      input projection (group-broadcast upstream)
  C  : (BH, L, N)      output projection
  A  : (BH, 1)         per-head negative decay (SMEM)
  h0 : (BH, P, N)      initial state
Outputs: y (BH, L, P) and final state (BH, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

DEFAULT_CHUNK = 128


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                state_ref, *, chunk: int, nc: int, seq_len: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0].astype(jnp.float32)

    A = a_ref[0, 0]
    x = x_ref[0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)          # (Q, 1)
    Bm = b_ref[0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)           # (Q, N)

    # zero out padded tail tokens (dt=0 -> identity step, zero input)
    tpos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    dt = jnp.where(tpos < seq_len, dt, 0.0)

    logdA = dt * A                               # (Q, 1), <= 0
    cum = jnp.cumsum(logdA, axis=0)              # inclusive
    # intra-chunk: M[t, s] = exp(cum_t - cum_s) * (C_t . B_s) * dt_s, s <= t
    decay = jnp.exp(cum - cum.T)                 # (Q, Q) via broadcast
    q_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = q_iota >= s_iota
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    M = jnp.where(tri, decay * cb * dt.T, 0.0)
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_inter[t] = exp(cum_t) * C_t . h_prev
    h = state_ref[...]                           # (P, N)
    ch = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, P)
    y_inter = jnp.exp(cum) * ch

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(cum_last) * h + sum_s exp(cum_last - cum_s) dt_s x_s B_s^T
    last = cum[chunk - 1, 0]
    w = jnp.exp(last - cum) * dt                 # (Q, 1)
    xw = x * w                                   # (Q, P)
    S = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (P, N)
    state_ref[...] = h * jnp.exp(last) + S

    @pl.when(ci == nc - 1)
    def _finalize():
        hout_ref[0] = state_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(x, dt, A, B, C, D=None, *, chunk=DEFAULT_CHUNK,
                    initial_state=None, interpret=False):
    """Semantics of ``ref.ssd_chunked_ref`` (group-broadcast + flatten here).

    x : (Bb, L, H, P); dt : (Bb, L, H); A : (H,); B, C : (Bb, L, G, N).
    Returns (y (Bb, L, H, P), state (Bb, H, P, N)).
    """
    Bb, L, H, P = x.shape
    _, _, G, N = B.shape
    rep = H // G
    Q = min(chunk, max(8, L))
    Lp = -(-L // Q) * Q

    def padt(a):
        return jnp.pad(a, ((0, 0), (0, Lp - L)) + ((0, 0),) * (a.ndim - 2))

    xt = padt(x).transpose(0, 2, 1, 3).reshape(Bb * H, Lp, P)
    dtt = padt(dt).transpose(0, 2, 1).reshape(Bb * H, Lp, 1)
    Bh = jnp.repeat(padt(B), rep, axis=2).transpose(0, 2, 1, 3)
    Ch = jnp.repeat(padt(C), rep, axis=2).transpose(0, 2, 1, 3)
    Bh = Bh.reshape(Bb * H, Lp, N)
    Ch = Ch.reshape(Bb * H, Lp, N)
    Ab = jnp.broadcast_to(A[None], (Bb, H)).reshape(Bb * H, 1)
    Ab = Ab.astype(jnp.float32)
    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    h0 = h0.reshape(Bb * H, P, N)

    nc = Lp // Q
    grid = (Bb * H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=Q, nc=nc, seq_len=L)

    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, Q, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb * H, Lp, P), x.dtype),
            jax.ShapeDtypeStruct((Bb * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(Ab, xt, dtt, Bh, Ch, h0)

    y = y.reshape(Bb, H, Lp, P).transpose(0, 2, 1, 3)[:, :L]
    if D is not None:
        y = (y.astype(jnp.float32)
             + x.astype(jnp.float32) * D[None, None, :, None]).astype(x.dtype)
    return y, hout.reshape(Bb, H, P, N)
