"""Pallas TPU grouped (per-expert) matmul kernel.

Computes (E, C, K) @ (E, K, N) -> (E, C, N) — the expert-FFN GEMM after
capacity-based dispatch.  Grid = (E, C_blocks, N_blocks, K_blocks) with the
contraction dimension sequential and an fp32 accumulator tile in VMEM, so
arbitrary K (d_model or d_ff, up to 32k for grok) streams through VMEM in
MXU-aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

DEFAULT_BLOCK_C = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 512


def _gemm_kernel(lhs_ref, rhs_ref, out_ref, acc_ref, *, nk: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[0], rhs_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def grouped_matmul_pallas(lhs, rhs, *, block_c=DEFAULT_BLOCK_C,
                          block_n=DEFAULT_BLOCK_N, block_k=DEFAULT_BLOCK_K,
                          interpret=False):
    """lhs: (E, C, K); rhs: (E, K, N) -> (E, C, N)."""
    E, C, K = lhs.shape
    _, _, N = rhs.shape
    block_c = min(block_c, max(8, C))
    block_n = min(block_n, max(8, N))
    block_k = min(block_k, max(8, K))
    Cp = -(-C // block_c) * block_c
    Kp = -(-K // block_k) * block_k
    Np = -(-N // block_n) * block_n
    lp = jnp.pad(lhs, ((0, 0), (0, Cp - C), (0, Kp - K)))
    rp = jnp.pad(rhs, ((0, 0), (0, Kp - K), (0, Np - N)))

    nk = Kp // block_k
    grid = (E, Cp // block_c, Np // block_n, nk)
    kernel = functools.partial(_gemm_kernel, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda e, ci, ni, ki: (e, ci, ki)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda e, ci, ni, ki: (e, ki, ni)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_n),
                               lambda e, ci, ni, ki: (e, ci, ni)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Np), lhs.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_n), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(lp, rp)
    return out[:, :C, :N]
