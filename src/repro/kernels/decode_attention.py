"""Pallas TPU flash-decode kernels: one new token vs a long KV cache.

The decode hot loop is memory-bound (stream the whole cache once per token),
so the kernel's job is to keep the cache stream dense: grid = (batch*q_heads,
kv_blocks), kv sequential with (m, l, acc) carried in VMEM scratch — the
same online-softmax recurrence as prefill but with a single query row
broadcast across the sublane dimension.

Valid-length masking comes from a per-batch ``cache_len`` operand (int32,
one scalar per bh row) so ragged caches batch together; sliding windows
mask to the trailing ``window`` positions.

Two cache layouts are supported:

* **dense** — contiguous ``(B, S, Hkv, D)`` caches
  (``decode_attention_pallas``);
* **paged** — the serving arena's block-pool layout: physical pages
  ``(P, block_size, Hkv, D)`` plus a ``(B, blocks_per_slot)`` block table.
  ``paged_decode_attention_pallas`` scalar-prefetches the block table so
  each grid step's BlockSpec index map resolves logical block ``ki`` of
  batch ``b`` to its physical page — K/V stream straight from the pool
  with no gather materialization.  The model families' paged-native
  decode/chunk steps (``decode_step_paged`` / ``prefill_chunk_paged``)
  dispatch here through ``ops.paged_decode_attention`` /
  ``ops.paged_chunk_attention``; ``paged_gather_ref`` is the CPU/XLA
  fallback (per-slot gather through a ``mask_block_tables``-clipped
  table, then the dense kernel math).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

from .ref import NEG_INF

DEFAULT_KV_BLOCK = 512
_SUB = 8  # sublane rows the single query is broadcast over


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, window: Optional[int],
                   k_block: int, nk: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[0, 0]
    k_lo = ki * k_block
    visible = k_lo < cache_len
    if window is not None:
        visible = jnp.logical_and(visible, k_lo + k_block > cache_len - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (_SUB, D) rows equal
        k = k_ref[0].astype(jnp.float32)            # (k_block, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (_SUB, k_block), 1)
        ok = kpos < cache_len
        if window is not None:
            ok = jnp.logical_and(ok, kpos >= cache_len - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1]) * ok.astype(jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, cache_len, *, window=None,
                            softmax_scale=None, k_block=DEFAULT_KV_BLOCK,
                            interpret=False):
    """q: (B, Hq, D); caches: (B, S, Hkv, D); cache_len: scalar or (B,) int.

    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len, jnp.int32)

    k_block = min(k_block, max(8, S))
    S_p = -(-S // k_block) * k_block
    kt = jnp.pad(k_cache, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    vt = jnp.pad(v_cache, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    kt = kt.transpose(0, 2, 1, 3).reshape(B * Hkv, S_p, D)
    vt = vt.transpose(0, 2, 1, 3).reshape(B * Hkv, S_p, D)
    # broadcast the single query over _SUB sublane rows
    qt = jnp.broadcast_to(q.reshape(B * Hq, 1, D), (B * Hq, _SUB, D))
    lens = jnp.repeat(cache_len, Hq).reshape(B * Hq, 1)

    nk = S_p // k_block
    grid = (B * Hq, nk)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               k_block=k_block, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, _SUB, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, ki, group=group: (bh // group, ki, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, ki, group=group: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, _SUB, D), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, _SUB, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((_SUB, 128), jnp.float32),
            pltpu.VMEM((_SUB, 128), jnp.float32),
            pltpu.VMEM((_SUB, D), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qt, kt, vt)

    return out[:, 0].reshape(B, Hq, D)


# ---------------------------------------------------------------------------
# chunked prefill: a block of T query positions vs the (partial) cache
# ---------------------------------------------------------------------------

def _chunk_tile(start, end, ki, q, k, v, m_ref, l_ref, acc_ref,
                *, scale: float, prefix_len: int, k_block: int, Tp: int):
    """Shared online-softmax tile for the chunk-prefill kernels: query row
    i sits at absolute position ``start + i``; ``end`` = start + chunk_len
    bounds the valid cache (rows past chunk_len are padding and masked)."""
    q = q.astype(jnp.float32)                       # (Tp, D)
    k = k.astype(jnp.float32)                       # (k_block, D)
    v = v.astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_lo = ki * k_block
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (Tp, k_block), 1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Tp, k_block), 0)
    ok = kpos <= start + rows                       # causal over the cache
    if prefix_len:
        ok = jnp.logical_or(ok, kpos < prefix_len)  # bidirectional prefix
    ok = jnp.logical_and(ok, kpos < end)            # valid cache only
    ok = jnp.logical_and(ok, rows < end - start)    # padded q rows dead
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, :1]) * ok.astype(jnp.float32)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv


def _chunk_kernel(start_ref, end_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
                  l_ref, acc_ref, *, scale: float, prefix_len: int,
                  k_block: int, nk: int, Tp: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start, end = start_ref[0, 0], end_ref[0, 0]

    @pl.when(ki * k_block < end)
    def _compute():
        _chunk_tile(start, end, ki, q_ref[0], k_ref[0], v_ref[0],
                    m_ref, l_ref, acc_ref, scale=scale,
                    prefix_len=prefix_len, k_block=k_block, Tp=Tp)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def chunk_prefill_attention_pallas(q, k_cache, v_cache, start, chunk_len, *,
                                   prefix_len: int = 0, softmax_scale=None,
                                   k_block=DEFAULT_KV_BLOCK,
                                   interpret=False):
    """q: (B, T, Hq, D) chunk queries; caches: (B, S, Hkv, D) already
    holding the chunk's own K/V at positions [start, start+chunk_len);
    start/chunk_len: scalar or (B,) int.  Returns (B, T, Hq, D); rows past
    ``chunk_len`` are zeros.
    """
    B, T, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.full((B,), start, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    if chunk_len.ndim == 0:
        chunk_len = jnp.full((B,), chunk_len, jnp.int32)

    Tp = -(-T // _SUB) * _SUB                       # sublane-align q rows
    k_block = min(k_block, max(8, S))
    S_p = -(-S // k_block) * k_block
    kt = jnp.pad(k_cache, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    vt = jnp.pad(v_cache, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    kt = kt.transpose(0, 2, 1, 3).reshape(B * Hkv, S_p, D)
    vt = vt.transpose(0, 2, 1, 3).reshape(B * Hkv, S_p, D)
    qt = q.transpose(0, 2, 1, 3)                    # (B, Hq, T, D)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    qt = qt.reshape(B * Hq, Tp, D)
    starts = jnp.repeat(start, Hq).reshape(B * Hq, 1)
    ends = jnp.repeat(start + chunk_len, Hq).reshape(B * Hq, 1)

    nk = S_p // k_block
    grid = (B * Hq, nk)
    kernel = functools.partial(_chunk_kernel, scale=scale,
                               prefix_len=prefix_len, k_block=k_block,
                               nk=nk, Tp=Tp)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Tp, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, ki, group=group: (bh // group, ki, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda bh, ki, group=group: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, Tp, D), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Tp, 128), jnp.float32),
            pltpu.VMEM((Tp, 128), jnp.float32),
            pltpu.VMEM((Tp, D), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(starts, ends, qt, kt, vt)

    out = out.reshape(B, Hq, Tp, D)[:, :, :T]
    return out.transpose(0, 2, 1, 3)


def _paged_chunk_kernel(bt_ref, start_ref, end_ref, q_ref, k_ref, v_ref,
                        o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                        prefix_len: int, k_block: int, nk: int, Tp: int,
                        q_heads: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[bh // q_heads]
    end = end_ref[bh // q_heads]

    # a logical block at or past the valid cache maps to the trash page
    @pl.when(ki * k_block < end)
    def _compute():
        _chunk_tile(start, end, ki, q_ref[0], k_ref[0, 0],
                    v_ref[0, 0], m_ref, l_ref, acc_ref, scale=scale,
                    prefix_len=prefix_len, k_block=k_block, Tp=Tp)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def paged_chunk_prefill_attention_pallas(q, k_pages, v_pages, block_tables,
                                         start, chunk_len, *,
                                         prefix_len: int = 0,
                                         softmax_scale=None,
                                         interpret=False):
    """Chunked-prefill attention straight through the serving arena's block
    table: q (B, T, Hq, D) chunk queries; pages (P, block_size, Hkv, D);
    block_tables (B, blocks_per_slot) int32; start/chunk_len (B,) int32.
    The chunk's own K/V must already be scattered into the pages (the
    engine writes pages before attending).  Returns (B, T, Hq, D).

    Like ``paged_decode_attention_pallas``, the table rides in scalar-
    prefetch SMEM so the K/V BlockSpec index maps stream physical pages in
    logical order; ``ops.paged_chunk_attention`` provides the dense-gather
    CPU fallback.

    This kernel is also the speculative-decoding VERIFY launch
    (``ops.paged_verify_attention``): T = k+1 rows score
    ``[last_emitted, d_1 .. d_k]`` in one call, with ``chunk_len`` a
    per-slot vector that is 0 for non-speculating rows of the fixed-
    capacity batch.  A zero-length row attends over an empty range — its
    softmax normalizer is 0 and the output row is garbage/NaN by design;
    the engine's verifier masks those rows and the row's K/V writes were
    routed to the trash page upstream.  No verify-specific kernel exists
    because the per-(B,) length plumbing below already expresses it.
    """
    B, T, Hq, D = q.shape
    P, k_block, Hkv, _ = k_pages.shape
    nk = block_tables.shape[1]
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.full((B,), start, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    if chunk_len.ndim == 0:
        chunk_len = jnp.full((B,), chunk_len, jnp.int32)
    tables = jnp.asarray(block_tables, jnp.int32)

    Tp = -(-T // _SUB) * _SUB
    kp = k_pages.transpose(2, 0, 1, 3)             # (Hkv, P, bs, D)
    vp = v_pages.transpose(2, 0, 1, 3)
    qt = q.transpose(0, 2, 1, 3)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    qt = qt.reshape(B * Hq, Tp, D)

    def kv_index(bh, ki, bt_ref, s_ref, e_ref):
        b = bh // Hq
        kvh = (bh % Hq) // group
        return (kvh, bt_ref[b, ki], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                     # table + start + end
        grid=(B * Hq, nk),
        in_specs=[
            pl.BlockSpec((1, Tp, D),
                         lambda bh, ki, bt, s, e: (bh, 0, 0)),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, Tp, D),
                               lambda bh, ki, bt, s, e: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Tp, 128), jnp.float32),
            pltpu.VMEM((Tp, 128), jnp.float32),
            pltpu.VMEM((Tp, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_chunk_kernel, scale=scale,
                               prefix_len=prefix_len, k_block=k_block,
                               nk=nk, Tp=Tp, q_heads=Hq)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tp, D), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, start, start + chunk_len, qt, kp, vp)

    out = out.reshape(B, Hq, Tp, D)[:, :, :T]
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# paged layout: K/V read through a block table (serving arena fast path)
# ---------------------------------------------------------------------------

def paged_gather_ref(pages, block_tables):
    """Dense-gather fallback: pages (P, bs, *rest) + tables (B, nblk)
    -> contiguous (B, nblk*bs, *rest).  ``rest`` is (Hkv, D) for value
    pools and (Hkv,) for the int8 pools' scale siblings.  Unallocated
    table entries point at the pool's trash block; callers mask them via
    ``cache_len``."""
    B, nblk = block_tables.shape
    _, bs, *rest = pages.shape
    g = pages[block_tables]                    # (B, nblk, bs, *rest)
    return g.reshape(B, nblk * bs, *rest)


def mask_block_tables(block_tables, valid_len, block_size, trash):
    """Route every table entry wholly past ``valid_len`` to the ``trash``
    block before a ref-fallback gather.

    The Pallas kernels skip blocks at or past each slot's valid length via
    their ``@pl.when`` gates, so their HBM traffic scales with LIVE tokens.
    The CPU/XLA gather cannot shrink its (static) output, but it can stop
    streaming cold pages the softmax will mask anyway: with every
    past-``valid_len`` entry pointing at the one trash page, the gather
    reads per-slot up-to-len rows plus a single hot page instead of the
    slot's full pool — bit-identical outputs (masked positions never
    survive the softmax) with live-token-bound unique-byte traffic."""
    nblk = block_tables.shape[1]
    starts = jnp.arange(nblk, dtype=jnp.int32)[None] * block_size
    valid_len = jnp.asarray(valid_len, jnp.int32)
    if valid_len.ndim == 0:
        valid_len = jnp.full((block_tables.shape[0],), valid_len)
    return jnp.where(starts < valid_len[:, None], block_tables, trash)


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         k_block: int, nk: int, q_heads: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[bh // q_heads]
    k_lo = ki * k_block
    # a logical block past cache_len maps to the trash page: skip it
    @pl.when(k_lo < cache_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # (_SUB, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (k_block, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (_SUB, k_block), 1)
        ok = kpos < cache_len
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1]) * ok.astype(jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pages, v_pages, block_tables,
                                  cache_len, *, softmax_scale=None,
                                  interpret=False):
    """q: (B, Hq, D); pages: (P, block_size, Hkv, D); block_tables:
    (B, blocks_per_slot) int32; cache_len: (B,) int32.  Returns (B, Hq, D).

    The block table rides in scalar-prefetch SMEM so the K/V BlockSpec
    index maps dereference it — the kernel streams physical pages in
    logical order without ever building the contiguous view.
    """
    B, Hq, D = q.shape
    P, k_block, Hkv, _ = k_pages.shape
    nk = block_tables.shape[1]
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len, jnp.int32)
    tables = jnp.asarray(block_tables, jnp.int32)

    # per-kv-head page pools so one (head, physical block) pair is a tile
    kp = k_pages.transpose(2, 0, 1, 3)             # (Hkv, P, bs, D)
    vp = v_pages.transpose(2, 0, 1, 3)
    qt = jnp.broadcast_to(q.reshape(B * Hq, 1, D), (B * Hq, _SUB, D))

    def kv_index(bh, ki, bt_ref, len_ref):
        b = bh // Hq
        kvh = (bh % Hq) // group
        return (kvh, bt_ref[b, ki], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block table + lens
        grid=(B * Hq, nk),
        in_specs=[
            pl.BlockSpec((1, _SUB, D), lambda bh, ki, bt, ln: (bh, 0, 0)),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, _SUB, D), lambda bh, ki, bt, ln:
                               (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((_SUB, 128), jnp.float32),
            pltpu.VMEM((_SUB, 128), jnp.float32),
            pltpu.VMEM((_SUB, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               k_block=k_block, nk=nk, q_heads=Hq)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, _SUB, D), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, cache_len, qt, kp, vp)

    return out[:, 0].reshape(B, Hq, D)


# ---------------------------------------------------------------------------
# quantized paged layout: int8 page tiles + scalar-prefetched scale columns,
# dequantized in-register before QK/PV (the pool never exists in float)
# ---------------------------------------------------------------------------

def _quant_scale_pool(scales):
    """(P, bs, Hkv) f32 scale pool -> (Hkv, P, bs, 1): same per-kv-head
    physical-page tiling as the value pools, with a lane-dim singleton so
    the (k_block, 1) scale column broadcasts against (k_block, D) tiles."""
    return scales.transpose(2, 0, 1)[..., None]


def _paged_decode_kernel_quant(bt_ref, len_ref, q_ref, k_ref, v_ref,
                               ks_ref, vs_ref, o_ref, m_ref, l_ref,
                               acc_ref, *, scale: float, k_block: int,
                               nk: int, q_heads: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[bh // q_heads]
    k_lo = ki * k_block
    # a logical block past cache_len maps to the trash page: skip it
    @pl.when(k_lo < cache_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # (_SUB, D)
        # dequantize in-register: int8 tile * per-row scale column
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]  # (k_block, D)
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (_SUB, k_block), 1)
        ok = kpos < cache_len
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1]) * ok.astype(jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def paged_decode_attention_quant_pallas(q, k_pages, v_pages, k_scales,
                                        v_scales, block_tables, cache_len,
                                        *, softmax_scale=None,
                                        interpret=False):
    """Quantized sibling of ``paged_decode_attention_pallas``: pages are
    int8 (P, block_size, Hkv, D) with f32 scales (P, block_size, Hkv); the
    kernel streams int8 tiles + scale columns through the block table and
    dequantizes in-register — HBM decode traffic is 1 byte per KV element
    plus 4/D bytes of scale.
    """
    B, Hq, D = q.shape
    P, k_block, Hkv, _ = k_pages.shape
    nk = block_tables.shape[1]
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len, jnp.int32)
    tables = jnp.asarray(block_tables, jnp.int32)

    kp = k_pages.transpose(2, 0, 1, 3)             # (Hkv, P, bs, D) int8
    vp = v_pages.transpose(2, 0, 1, 3)
    ks = _quant_scale_pool(k_scales)               # (Hkv, P, bs, 1) f32
    vs = _quant_scale_pool(v_scales)
    qt = jnp.broadcast_to(q.reshape(B * Hq, 1, D), (B * Hq, _SUB, D))

    def kv_index(bh, ki, bt_ref, len_ref):
        b = bh // Hq
        kvh = (bh % Hq) // group
        return (kvh, bt_ref[b, ki], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block table + lens
        grid=(B * Hq, nk),
        in_specs=[
            pl.BlockSpec((1, _SUB, D), lambda bh, ki, bt, ln: (bh, 0, 0)),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
            pl.BlockSpec((1, 1, k_block, 1), kv_index),
            pl.BlockSpec((1, 1, k_block, 1), kv_index),
        ],
        out_specs=pl.BlockSpec((1, _SUB, D), lambda bh, ki, bt, ln:
                               (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((_SUB, 128), jnp.float32),
            pltpu.VMEM((_SUB, 128), jnp.float32),
            pltpu.VMEM((_SUB, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel_quant, scale=scale,
                               k_block=k_block, nk=nk, q_heads=Hq)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, _SUB, D), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, cache_len, qt, kp, vp, ks, vs)

    return out[:, 0].reshape(B, Hq, D)


def _paged_chunk_kernel_quant(bt_ref, start_ref, end_ref, q_ref, k_ref,
                              v_ref, ks_ref, vs_ref, o_ref, m_ref, l_ref,
                              acc_ref, *, scale: float, prefix_len: int,
                              k_block: int, nk: int, Tp: int, q_heads: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[bh // q_heads]
    end = end_ref[bh // q_heads]

    # a logical block at or past the valid cache maps to the trash page
    @pl.when(ki * k_block < end)
    def _compute():
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
        _chunk_tile(start, end, ki, q_ref[0], k, v, m_ref, l_ref,
                    acc_ref, scale=scale, prefix_len=prefix_len,
                    k_block=k_block, Tp=Tp)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def paged_chunk_prefill_attention_quant_pallas(q, k_pages, v_pages,
                                               k_scales, v_scales,
                                               block_tables, start,
                                               chunk_len, *,
                                               prefix_len: int = 0,
                                               softmax_scale=None,
                                               interpret=False):
    """Quantized sibling of ``paged_chunk_prefill_attention_pallas``: the
    chunk's own rows must already be *quantized* into the int8 pages (the
    write path quantizes before attending), so the kernel's dequantized
    view is exactly what decode will later read."""
    B, T, Hq, D = q.shape
    P, k_block, Hkv, _ = k_pages.shape
    nk = block_tables.shape[1]
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.full((B,), start, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    if chunk_len.ndim == 0:
        chunk_len = jnp.full((B,), chunk_len, jnp.int32)
    tables = jnp.asarray(block_tables, jnp.int32)

    Tp = -(-T // _SUB) * _SUB
    kp = k_pages.transpose(2, 0, 1, 3)             # (Hkv, P, bs, D) int8
    vp = v_pages.transpose(2, 0, 1, 3)
    ks = _quant_scale_pool(k_scales)               # (Hkv, P, bs, 1) f32
    vs = _quant_scale_pool(v_scales)
    qt = q.transpose(0, 2, 1, 3)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    qt = qt.reshape(B * Hq, Tp, D)

    def kv_index(bh, ki, bt_ref, s_ref, e_ref):
        b = bh // Hq
        kvh = (bh % Hq) // group
        return (kvh, bt_ref[b, ki], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                     # table + start + end
        grid=(B * Hq, nk),
        in_specs=[
            pl.BlockSpec((1, Tp, D),
                         lambda bh, ki, bt, s, e: (bh, 0, 0)),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
            pl.BlockSpec((1, 1, k_block, D), kv_index),
            pl.BlockSpec((1, 1, k_block, 1), kv_index),
            pl.BlockSpec((1, 1, k_block, 1), kv_index),
        ],
        out_specs=pl.BlockSpec((1, Tp, D),
                               lambda bh, ki, bt, s, e: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Tp, 128), jnp.float32),
            pltpu.VMEM((Tp, 128), jnp.float32),
            pltpu.VMEM((Tp, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_chunk_kernel_quant, scale=scale,
                               prefix_len=prefix_len, k_block=k_block,
                               nk=nk, Tp=Tp, q_heads=Hq)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tp, D), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, start, start + chunk_len, qt, kp, vp, ks, vs)

    out = out.reshape(B, Hq, Tp, D)[:, :, :T]
    return out.transpose(0, 2, 1, 3)
