"""mixtral-8x7b [moe] — 8 experts top-2, native SWA [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, sliding window 4096.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    citation="arXiv:2401.04088 (Mixtral of Experts)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    epara_sensitivity="latency",
    epara_multi_gpu=True,
)
