"""mamba2-2.7b [ssm] — SSD, attention-free [arXiv:2405.21060].

64L d_model=2560, ssm_state=128, headdim=64 (=> 80 SSD heads), vocab=50280.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    citation="arXiv:2405.21060 (Mamba-2 / SSD)",
    num_layers=64,
    d_model=2560,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    epara_sensitivity="frequency",
    epara_multi_gpu=False,
)
