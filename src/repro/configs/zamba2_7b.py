"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention [arXiv:2411.15242].

81L d_model=3584 32H (kv=32) d_ff=14336, ssm_state=64.  One shared
attention+MLP block over concat(h, h0) applied every 6 mamba layers.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    citation="arXiv:2411.15242 (Zamba2)",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    attn_every=6,
    epara_sensitivity="frequency",
    epara_multi_gpu=False,
)
