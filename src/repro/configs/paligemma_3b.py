"""paligemma-3b [vlm] — SigLIP + gemma [arXiv:2407.07726].

18L d_model=2048 8H (MQA kv=1, head_dim 256) d_ff=16384 vocab=257216.
SigLIP tower + projector are a STUB: input_specs feeds 256 patch
embeddings; this config is the gemma-2b language backbone with prefix-LM
masking over the image prefix.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    citation="arXiv:2407.07726 (PaliGemma)",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="geglu",
    tie_embeddings=True,
    prefix_len=256,
    epara_sensitivity="latency",
    epara_multi_gpu=False,
)
