"""minicpm-2b [dense] — WSD schedule, llama-like arch [arXiv:2404.06395].

40L d_model=2304 36H (GQA kv=36 = MHA) d_ff=5760 vocab=122753.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    citation="arXiv:2404.06395 (MiniCPM)",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    epara_sensitivity="frequency",   # HCI-style continuous requests (§4.3)
    epara_multi_gpu=False,
)
