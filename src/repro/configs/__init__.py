"""Assigned-architecture configs (``--arch <id>``) + input shapes.

Every config cites its source model card / paper.  ``long_context_variant``
returns the explicitly-flagged sliding-window variant used for the
``long_500k`` shape on pure full-attention archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.config import (INPUT_SHAPES, SHAPES_BY_NAME, ModelConfig,
                                 ShapeSpec, reduced)

from . import (codeqwen1_5_7b, grok_1_314b, mamba2_2_7b, minicpm_2b,
               minitron_4b, mistral_large_123b, mixtral_8x7b, paligemma_3b,
               whisper_large_v3, zamba2_7b)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (mistral_large_123b, minitron_4b, minicpm_2b, grok_1_314b,
              whisper_large_v3, mixtral_8x7b, paligemma_3b, zamba2_7b,
              mamba2_2_7b, codeqwen1_5_7b)
}

ARCH_IDS: List[str] = list(ARCHS)

LONG_CONTEXT_WINDOW = 4096


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}") from None


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """SWA variant for long_500k on pure full-attention archs.  Natively
    sub-quadratic families (ssm/hybrid/native-SWA) are returned unchanged;
    full-attention archs get an explicit sliding window (this is a variant,
    not the paper model — recorded per-run in EXPERIMENTS.md)."""
    if cfg.sub_quadratic:
        return cfg
    return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)


def config_for_shape(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    return cfg


__all__ = ["ARCHS", "ARCH_IDS", "INPUT_SHAPES", "SHAPES_BY_NAME",
           "get_config", "long_context_variant", "config_for_shape",
           "reduced", "ModelConfig", "ShapeSpec"]
