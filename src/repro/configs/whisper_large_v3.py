"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].

32L d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.  The mel/conv
frontend is stubbed per the assignment: input_specs feeds 1500 frame
embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    citation="arXiv:2212.04356 (Whisper); large-v3 model card",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_len=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    norm="layernorm",
    activation="gelu_mlp",
    epara_sensitivity="frequency",  # streaming ASR = frame-continuous
    epara_multi_gpu=False,
)
