"""Training step: chunked cross-entropy loss + grads + optimizer update.

The loss scans the sequence in chunks so the (B, L, vocab) logits tensor is
never materialized — at minitron-4b's 256k vocab and 1M tokens the full
tensor would be ~0.5 TB; chunking bounds the transient to
(B, chunk, vocab) per device (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import model_api

DEFAULT_LOSS_CHUNK = 512
MOE_AUX_WEIGHT = 0.01


def chunked_cross_entropy(hidden, labels, logits_fn, *,
                          chunk: int = DEFAULT_LOSS_CHUNK,
                          ignore_id: int = -1):
    """hidden: (B, L, d); labels: (B, L).  Mean NLL over non-ignored
    positions, computed chunk-by-chunk over L via lax.map."""
    B, L, d = hidden.shape
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=ignore_id)
    n_chunks = hidden.shape[1] // chunk
    hidden = hidden.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    labels = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def per_chunk(args):
        # remat: the (B, chunk, V) logits are recomputed in the backward
        # instead of being saved per chunk (they alone would be ~16 GB/dev
        # for minicpm-2b train_4k — EXPERIMENTS.md §Dry-run)
        h, y = args
        logits = logits_fn(h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = (y != ignore_id).astype(jnp.float32)
        return jnp.sum(nll * mask), jnp.sum(mask)

    losses, counts = jax.lax.map(per_chunk, (hidden, labels))
    total = jnp.sum(losses)
    n = jnp.maximum(jnp.sum(counts), 1.0)
    return total / n


def make_loss_fn(cfg: ModelConfig, *, loss_chunk: int = DEFAULT_LOSS_CHUNK,
                 impl: Optional[str] = None) -> Callable:
    api = model_api(cfg)

    def loss_fn(params, batch: Dict[str, Any]):
        hidden, aux = api.forward_hidden(params, cfg, batch, train=True,
                                         impl=impl)
        labels = batch["labels"]
        if cfg.family == "vlm":  # loss over the text region only
            hidden = hidden[:, cfg.prefix_len:]
        lf = lambda h: api.logits_fn(params, cfg, h)
        loss = chunked_cross_entropy(hidden, labels, lf, chunk=loss_chunk)
        return loss + MOE_AUX_WEIGHT * aux, {"nll": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer, *,
                    loss_chunk: int = DEFAULT_LOSS_CHUNK,
                    num_microbatches: int = 1,
                    accum_dtype=jnp.float32,
                    impl: Optional[str] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  pjit-ready: pure, no python state.

    ``num_microbatches > 1`` scans the global batch in chunks with fp32
    gradient accumulation: live activation memory scales with B/k while the
    optimizer update still sees the full-batch gradient — required to fit
    train_4k for the 100B+ configs (EXPERIMENTS.md §Dry-run)."""
    loss_fn = make_loss_fn(cfg, loss_chunk=loss_chunk, impl=impl)
    k = num_microbatches

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def reshard(x):
                B = x.shape[0]
                assert B % k == 0, f"batch {B} % microbatches {k}"
                return x.reshape(k, B // k, *x.shape[1:])

            micro = jax.tree.map(reshard, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def acc_body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype) / k, g_acc, g)
                return (g_acc, loss_acc + loss / k,
                        aux_acc + metrics["aux"] / k), None

            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), micro)
            metrics = {"nll": loss, "aux": aux}
        new_params, new_state = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_params, new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *,
                   loss_chunk: int = DEFAULT_LOSS_CHUNK,
                   impl: Optional[str] = None) -> Callable:
    loss_fn = make_loss_fn(cfg, loss_chunk=loss_chunk, impl=impl)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)

    return eval_step
