"""Minimal sharding-agnostic checkpointing: pytrees <-> .npz archives.

Leaves are addressed by their tree path ("blocks/attn/wq", tuple indices as
digits) so restores are order-independent and partial restores (e.g. params
only, no optimizer state) are possible.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree, *, step: Optional[int] = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)
    return path if path.endswith(".npz") else path + ".npz"


def restore(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes preserved)."""
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path)
    flat_like = _flatten(like)
    missing = [k for k in flat_like if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint {path} missing keys: {missing[:5]}...")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    restored = [jax.numpy.asarray(data[k]).astype(leaf.dtype).reshape(
        leaf.shape) for k, leaf in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored)


def restored_step(path: str) -> Optional[int]:
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path)
    return int(data["__step__"]) if "__step__" in data.files else None
