"""Optimizers on raw pytrees (no optax dependency): AdamW and Adafactor.

Adafactor's factored second moment keeps optimizer state ~O(n+m) per (n,m)
matrix — the difference between grok-1-314b fitting a single 256-chip pod
during the training dry-run (~9.8 GB/chip) and OOMing (~17 GB/chip with
AdamW, see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) *
                          g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                          jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32)
                    - self.learning_rate * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any    # row second-moment (or full v for <2D leaves)
    vc: Any    # col second-moment (zeros-dim placeholder for <2D leaves)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second-moment (Shazeer & Stern 2018), no first moment."""
    learning_rate: float = 3e-4
    decay: float = 0.8        # step-dependent: 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    @staticmethod
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(self, params) -> AdafactorState:
        def vr_init(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr_init, params),
                              vc=jax.tree.map(vc_init, params))

    def update(self, grads, state: AdafactorState, params
               ) -> Tuple[Any, AdafactorState]:
        step = state.step + 1
        beta = 1.0 - step.astype(jnp.float32) ** (-self.decay)

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if self._factored(p):
                vr_new = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc_new = beta * vc + (1 - beta) * g2.mean(axis=-2)
                denom = vr_new.mean(axis=-1, keepdims=True)
                r = vr_new / jnp.maximum(denom, self.eps)
                v = r[..., None] * vc_new[..., None, :]
            else:
                vr_new = beta * vr + (1 - beta) * g2
                vc_new = vc
                v = vr_new
            u = g / jnp.sqrt(jnp.maximum(v, self.eps))
            norm = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, norm / self.clip_threshold)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self.learning_rate * u
            return new_p.astype(p.dtype), vr_new, vc_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        out = [upd(p, g, vr, vc)
               for p, g, vr, vc in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_vr = treedef.unflatten([o[1] for o in out])
        new_vc = treedef.unflatten([o[2] for o in out])
        return new_params, AdafactorState(step=step, vr=new_vr, vc=new_vc)


def get_optimizer(name: str, learning_rate: float = 3e-4):
    if name == "adamw":
        return AdamW(learning_rate=learning_rate)
    if name == "adafactor":
        return Adafactor(learning_rate=learning_rate)
    raise ValueError(f"unknown optimizer {name!r}")
