"""Token samplers for the serving engine."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled


def sample(logits, key, cfg: SamplerConfig = SamplerConfig(), *,
           live=None, fill_token: int = 0):
    """logits: (B, V) -> (B,) int32.

    ``live`` is an optional (B,) bool mask for the slot engine: slots that
    already finished (EOS / their own ``max_new_tokens``) but still occupy
    a decode slot until the next evict pass must not emit real tokens —
    their rows are overwritten with ``fill_token`` so the fused batch-wide
    sample stays shape-stable and deterministic regardless of which slots
    are done."""
    if cfg.temperature <= 0.0:
        out = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        scaled = logits.astype(jnp.float32) / cfg.temperature
        if cfg.top_k > 0:
            top_vals, _ = jax.lax.top_k(scaled, cfg.top_k)
            cutoff = top_vals[:, -1:]
            scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)
        out = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    if live is not None:
        out = jnp.where(jnp.asarray(live), out,
                        jnp.asarray(fill_token, jnp.int32))
    return out
