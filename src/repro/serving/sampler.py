"""Token samplers for the serving engine."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled


def sample(logits, key, cfg: SamplerConfig = SamplerConfig()):
    """logits: (B, V) -> (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        top_vals, _ = jax.lax.top_k(logits, cfg.top_k)
        cutoff = top_vals[:, -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
