"""Token samplers for the serving engine.

Per-slot counter-based PRNG streams
-----------------------------------

A slot's sample stream must be a *pure function of the request*, never of
batch composition: the engine decodes at full static capacity, slots are
admitted/evicted/parked in arbitrary order, and a batch-wide
``jax.random.split`` would make every sampled token depend on which other
slots happen to be live.  Instead each draw derives its key by folding a
counter chain into one base key:

    key = fold_in(fold_in(fold_in(fold_in(base, seed), sample_idx),
                          stream), offset)

* ``seed`` — the request's seed (defaults to its rid);
* ``sample_idx`` — which of the request's n parallel samples this row is;
* ``stream`` — which consumer is drawing (``STREAM_DECODE`` for the
  ordinary one-token-per-step path, ``STREAM_DRAFT`` for draft-model
  proposals, ``STREAM_VERIFY`` / ``STREAM_CORRECTION`` for speculative
  rejection sampling) so speculation never perturbs the decode stream;
* ``offset`` — the emitted length at which the draw happens, i.e. a
  per-request monotonic counter.

Greedy sampling (``temperature <= 0``) never touches a key at all, which is
what makes park/resume, speculative on/off, and batch-composition changes
bit-identical for greedy services by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# Stream tags (the third fold_in in the counter chain).
STREAM_DECODE = 0      # the ordinary decode-loop sample
STREAM_DRAFT = 1       # draft-model proposals (speculative decoding)
STREAM_VERIFY = 2      # accept/reject uniforms in speculative_verify
STREAM_CORRECTION = 3  # residual/bonus draw in speculative_verify


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled


def slot_keys(base_key, seeds, sample_ids, offsets, stream: int = STREAM_DECODE):
    """Per-row keys from the counter chain: (B,) int arrays -> (B,) keys."""
    seeds = jnp.asarray(seeds, jnp.uint32)
    sample_ids = jnp.asarray(sample_ids, jnp.uint32)
    offsets = jnp.asarray(offsets, jnp.uint32)

    def one(seed, sidx, off):
        k = jax.random.fold_in(base_key, seed)
        k = jax.random.fold_in(k, sidx)
        k = jax.random.fold_in(k, jnp.uint32(stream))
        return jax.random.fold_in(k, off)

    return jax.vmap(one)(seeds, sample_ids, offsets)


def _filtered(logits, cfg: SamplerConfig):
    """Temperature-scaled, top_k-filtered logits (f32). temperature > 0."""
    scaled = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        top_vals, _ = jax.lax.top_k(scaled, cfg.top_k)
        cutoff = top_vals[..., -1:]
        scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)
    return scaled


def _apply_mask(out, live, occupancy, fill_token):
    mask = None
    if live is not None:
        mask = jnp.asarray(live)
    if occupancy is not None:
        occ = jnp.asarray(occupancy)
        mask = occ if mask is None else jnp.logical_and(mask, occ)
    if mask is not None:
        out = jnp.where(mask, out, jnp.asarray(fill_token, jnp.int32))
    return out, mask


def sample(logits, key, cfg: SamplerConfig = SamplerConfig(), *,
           live=None, occupancy=None, fill_token: int = 0):
    """logits: (B, V) -> (B,) int32 — single shared key (sync/batch path).

    Two optional (B,) bool masks keep the fused batch-wide sample
    shape-stable and deterministic regardless of which rows are real:

    * ``occupancy`` — the paged arena decodes at full static capacity, so
      rows of unoccupied slots carry garbage logits and must never emit;
    * ``live`` — slots that already finished (EOS / their own
      ``max_new_tokens``) but still hold a slot until the next evict pass.

    Rows masked by either are overwritten with ``fill_token``.

    The continuous engine never uses this for stochastic sampling — it
    routes through :func:`sample_per_slot` so each row's stream is
    batch-composition independent.
    """
    if cfg.temperature <= 0.0:
        out = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        out = jax.random.categorical(key, _filtered(logits, cfg),
                                     axis=-1).astype(jnp.int32)
    out, _ = _apply_mask(out, live, occupancy, fill_token)
    return out


def sample_per_slot(logits, base_key, seeds, sample_ids, offsets,
                    cfg: SamplerConfig = SamplerConfig(), *,
                    stream: int = STREAM_DECODE,
                    live=None, occupancy=None, fill_token: int = 0):
    """logits: (B, V) -> (B,) int32 with per-row counter-based keys.

    Row ``i`` draws with ``slot_keys(base, seeds[i], sample_ids[i],
    offsets[i], stream)`` — a pure function of that request's identity and
    progress, so its token stream is bit-identical whether it runs alone,
    in a full batch, or across a park/resume cycle.  Greedy never touches
    a key.
    """
    if cfg.temperature <= 0.0:
        out = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        keys = slot_keys(base_key, seeds, sample_ids, offsets, stream)
        scaled = _filtered(logits, cfg)
        out = jax.vmap(
            lambda k, row: jax.random.categorical(k, row)
        )(keys, scaled).astype(jnp.int32)
    out, _ = _apply_mask(out, live, occupancy, fill_token)
    return out


def _masked_probs(logits, cfg: SamplerConfig):
    """Softmax under the SAME temperature/top_k filter sampling uses."""
    return jax.nn.softmax(_filtered(logits, cfg), axis=-1)


def speculative_verify(target_logits, draft_logits, draft_tokens,
                       base_key, seeds, sample_ids, offsets,
                       cfg: SamplerConfig = SamplerConfig(), *,
                       live=None, occupancy=None, fill_token: int = 0):
    """Accept/reject k draft tokens against one fused target launch.

    Shapes (T = k+1 verified positions):

    * ``target_logits`` — (B, T, V): the target model's logits after each
      of the T fed tokens ``[last_emitted, d_1 .. d_k]``; row ``j`` is the
      target distribution for the position draft token ``d_{j+1}``
      occupies, and row ``k`` is the bonus position.
    * ``draft_logits`` — (B, k, V): the draft distributions ``d_{j+1}``
      was sampled from (ignored under greedy).
    * ``draft_tokens`` — (B, k) int32: the proposals ``d_1 .. d_k``.
    * ``offsets`` — (B,): emitted length at the round's first verified
      position (the per-request stream counter).

    Returns ``(tokens, n_emit)`` — ``tokens`` (B, T) int32 holding the
    emitted tokens left-aligned (accepted drafts then the
    correction/bonus; tail is ``fill_token``), ``n_emit`` (B,) int32 in
    ``[0, T]`` (0 only for masked rows).

    Greedy (``temperature <= 0``) accepts the longest prefix where
    ``d_{j+1} == argmax(target[j])`` and emits argmaxes — bit-identical
    to the non-speculative oracle by construction, key-free.  Stochastic
    uses exact leave-one-out rejection sampling (accept ``d`` w.p.
    ``min(1, p(d)/q(d))``; on first reject draw from
    ``normalize(max(p-q, 0))``; on all-accept draw the bonus from the
    target), so emitted tokens are distributed exactly as sampling the
    target one token at a time.
    """
    B, T, V = target_logits.shape
    k = T - 1
    draft_tokens = draft_tokens.astype(jnp.int32)

    if cfg.temperature <= 0.0:
        targets = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # (B,T)
        match = draft_tokens == targets[:, :k]                          # (B,k)
        prefix = jnp.cumprod(match.astype(jnp.int32), axis=-1)
        n_acc = prefix.sum(axis=-1)                                     # (B,)
        out = targets
    else:
        p = _masked_probs(target_logits, cfg)                 # (B,T,V)
        q = _masked_probs(draft_logits, cfg)                  # (B,k,V)
        rows = jnp.arange(B)[:, None]
        cols = jnp.arange(k)[None, :]
        p_d = p[rows, cols, draft_tokens]                     # (B,k)
        q_d = q[rows, cols, draft_tokens]
        vkeys = slot_keys(base_key, seeds, sample_ids, offsets,
                          STREAM_VERIFY)
        u = jax.vmap(lambda kk: jax.random.uniform(kk, (k,)))(vkeys)
        accept = u * q_d <= p_d                               # (B,k)
        prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
        n_acc = prefix.sum(axis=-1)                           # (B,) in [0,k]
        # Residual distribution at the first rejected position; at the
        # bonus position (n_acc == k) the draft proposed nothing, so the
        # residual degenerates to the target itself (q := 0 there).
        q_pad = jnp.concatenate([q, jnp.zeros((B, 1, V), q.dtype)], axis=1)
        p_at = p[jnp.arange(B), n_acc]                        # (B,V)
        q_at = q_pad[jnp.arange(B), n_acc]
        resid = jnp.maximum(p_at - q_at, 0.0)
        rsum = resid.sum(axis=-1, keepdims=True)
        resid = jnp.where(rsum > 0, resid / jnp.maximum(rsum, 1e-30), p_at)
        ckeys = slot_keys(base_key, seeds, sample_ids, offsets,
                          STREAM_CORRECTION)
        corr = jax.vmap(
            lambda kk, pr: jax.random.categorical(kk, jnp.log(pr + 1e-30))
        )(ckeys, resid).astype(jnp.int32)
        pos = jnp.arange(T)[None, :]
        out = jnp.where(pos < n_acc[:, None], draft_tokens_padded(draft_tokens),
                        jnp.where(pos == n_acc[:, None], corr[:, None],
                                  jnp.asarray(fill_token, jnp.int32)))

    n_emit = n_acc + 1
    masked, mask = _apply_mask(jnp.ones((B,), jnp.int32), live, occupancy, 0)
    if mask is not None:
        n_emit = jnp.where(mask, n_emit, 0)
        out = jnp.where(mask[:, None], out, jnp.asarray(fill_token, jnp.int32))
    # Zero the tail past n_emit so garbage positions can't leak.
    pos = jnp.arange(T)[None, :]
    out = jnp.where(pos < n_emit[:, None], out,
                    jnp.asarray(fill_token, jnp.int32))
    return out.astype(jnp.int32), n_emit.astype(jnp.int32)


def draft_tokens_padded(draft_tokens):
    """(B, k) -> (B, k+1): pad one bogus column so draft/correction selects
    share a (B, T) shape (the pad is never selected — position ``k`` can
    only be the bonus draw)."""
    B = draft_tokens.shape[0]
    return jnp.concatenate(
        [draft_tokens, jnp.zeros((B, 1), draft_tokens.dtype)], axis=1)
