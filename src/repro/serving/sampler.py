"""Token samplers for the serving engine."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled


def sample(logits, key, cfg: SamplerConfig = SamplerConfig(), *,
           live=None, occupancy=None, fill_token: int = 0):
    """logits: (B, V) -> (B,) int32.

    Two optional (B,) bool masks keep the fused batch-wide sample
    shape-stable and deterministic regardless of which rows are real:

    * ``occupancy`` — the paged arena decodes at full static capacity, so
      rows of unoccupied slots carry garbage logits and must never emit;
    * ``live`` — slots that already finished (EOS / their own
      ``max_new_tokens``) but still hold a slot until the next evict pass.

    Rows masked by either are overwritten with ``fill_token``.
    """
    if cfg.temperature <= 0.0:
        out = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        scaled = logits.astype(jnp.float32) / cfg.temperature
        if cfg.top_k > 0:
            top_vals, _ = jax.lax.top_k(scaled, cfg.top_k)
            cutoff = top_vals[:, -1:]
            scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)
        out = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    mask = None
    if live is not None:
        mask = jnp.asarray(live)
    if occupancy is not None:
        occ = jnp.asarray(occupancy)
        mask = occ if mask is None else jnp.logical_and(mask, occ)
    if mask is not None:
        out = jnp.where(mask, out, jnp.asarray(fill_token, jnp.int32))
    return out
