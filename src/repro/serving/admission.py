"""Deadline-aware admission control: the request-granularity layer EPARA's
categorization implies but FIFO admission throws away.

The controller sits between the composers (``batching.py``) and the slot
engine (``engine.py``) and does three things, all in the CALLER'S clock
(the ``now`` passed to ``step()`` — wall time in the launcher, a logical
clock in benchmarks; every estimate below is learned from observed
``now`` deltas, so the two never mix):

* **Slack-ordered admission** (``StrictestDeadlineFirst``): pending
  ``QueuedItem``s are reordered by deadline slack — the remaining budget
  after subtracting the request's own estimated prefill + decode cost —
  so the next free slot always goes to the request closest to missing.
  The legacy FIFO order stays available as the ``ParallelPlan.admission``
  baseline knob ("fifo", the default: the controller is inert and the
  engine behaves exactly as before).

* **Explicit verdicts** — every request that does NOT get a slot carries
  exactly one ``Outcome`` verdict (no verdict-less drops):

  - ``DEADLINE_MISSED``: the slack estimate says it cannot finish
    anywhere in time (deadline passed, or its own service time alone
    exceeds the remaining budget) — shed before burning capacity;
  - ``OFFLOAD``: positive slack, but the local queue would burn it — a
    peer could still make the deadline, so the distributed handler
    (``core/handler.py``) should route it with its existing
    ``Outcome``/``Decision`` machinery;
  - ``CONGESTION``: hard local backpressure — the queue is beyond the
    congestion bound, shed from the laziest tail (this is the only
    verdict deadline-less requests can draw);
  - ``FAILED`` (issued by ``serving/failover.py``, never by this
    controller): the request was lost to a fault and every recovery
    avenue — timeout retries, peer re-routes, the bounded attempt
    budget — was exhausted.  Listed here because it shares the same
    ``AdmissionReject`` envelope and verdict accounting.

  Rejects surface per step through ``StepStats.rejected`` /
  ``StepStats.deadline_missed``/``congestion_rejects``/
  ``offload_verdicts``.

* **Preemption by block-table parking**: under pressure (zero free
  slots, an urgent head that would miss while waiting), the engine
  parks the laziest live decode slot — ``KVArena.park`` pops the slot's
  blocks WITHOUT releasing their references, so the KV stays resident
  while the slot itself frees.  The victim's request re-queues; its
  later re-admission stitches the parked blocks back via
  ``alloc(shared=...)`` — effectively a 100% prefix hit — restores the
  emitted tokens and device length, and continues bit-identically
  (greedy sampling; the PRNG key is unused at temperature 0).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.categories import Outcome
from .batching import QueuedItem

POLICY_FIFO = "fifo"
POLICY_SDF = "sdf"
ADMISSION_POLICIES = (POLICY_FIFO, POLICY_SDF)

_INF = float("inf")


@dataclasses.dataclass
class AdmissionReject:
    """One rejected request + its verdict (``StepStats.rejected`` entry).
    The launcher feeds OFFLOAD verdicts back into the control plane's
    handler so the request is forwarded instead of silently dropped."""
    req: Any                         # the GenerationRequest (or payload)
    verdict: Outcome
    now: float
    reason: str = ""
    attempts: int = 0                # placement attempts consumed before
    #                                  the verdict (failover retries)


@dataclasses.dataclass
class ParkedEntry:
    """Everything needed to resume a preempted request bit-identically:
    the frozen block list (one owned reference per block), the emitted
    tokens so far, and the device-side cache length at park time."""
    req: Any
    group: int                       # blocks are physical ids in THIS
    #                                  group's arena — resume must land here
    blocks: List[int]
    emitted: List[int]
    cache_len: int                   # device lens[slot] at park time
    consumed: int                    # prompt tokens prefilled at park time
    steps: int
    prefill_s: float
    admit_wall: float
    decode_start_wall: float
    admitted_s: float
    parked_s: float


class AdmissionController:
    """Slack accounting + verdict policy for one ``ServiceRuntime``.

    The controller owns the POLICY (who goes first, who is shed, who is
    preempted); the engine owns the MECHANISM (slots, arena, composer).
    All time estimates are EWMAs over the caller's clock:

    * ``_round_dt`` — ``now`` delta between consecutive engine steps (one
      fused decode round);
    * ``_svc_logical`` — admission→finish duration of completed requests.

    Before the first completion both are 0, so every estimate collapses
    to "free": a cold controller admits exactly like FIFO and only
    starts shedding/preempting once it has observed real service times —
    conservative by construction.
    """

    def __init__(self, runtime, policy: Optional[str] = None, *,
                 preempt: bool = True, congestion_factor: float = 8.0,
                 max_parked: Optional[int] = None):
        if policy is None:
            policy = getattr(runtime.plan, "admission", POLICY_FIFO)
        if policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"admission policy must be one of {ADMISSION_POLICIES}, "
                f"got {policy!r}")
        self.rt = runtime
        self.policy = policy
        self.preempt = bool(preempt)
        self.congestion_factor = float(congestion_factor)
        self._max_parked = max_parked
        self.parked: Dict[int, ParkedEntry] = {}     # rid -> entry
        self.verdicts: Dict[str, int] = {}           # cumulative, by value
        self.preemptions = 0                         # slots parked
        self.resumes = 0                             # parked re-admissions
        self._round_dt = 0.0
        self._svc_logical = 0.0
        self._last_now: Optional[float] = None

    # -- policy state ------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.policy == POLICY_SDF

    @property
    def max_parked(self) -> int:
        if self._max_parked is not None:
            return self._max_parked
        return self.rt.total_slots()

    def _count(self, outcome: Outcome) -> None:
        self.verdicts[outcome.value] = \
            self.verdicts.get(outcome.value, 0) + 1

    # -- clock-agnostic cost model ----------------------------------------
    def note_step(self, now: float) -> None:
        """Learn the caller's per-round clock advance (0 under a frozen
        clock — then every estimate is 0 and the policy never sheds on
        prediction, only on already-expired deadlines)."""
        if self._last_now is not None and now > self._last_now:
            dt = now - self._last_now
            self._round_dt = (dt if self._round_dt == 0.0
                              else 0.8 * self._round_dt + 0.2 * dt)
        self._last_now = now

    def observe(self, res) -> None:
        """Feed one completed ``GenerationResult``'s logical duration."""
        t = res.finished_s - res.admitted_s
        if t <= 0.0:
            return
        self._svc_logical = (t if self._svc_logical == 0.0
                             else 0.8 * self._svc_logical + 0.2 * t)

    def _rounds(self, req) -> float:
        """Engine rounds one queued request needs: its chunked-prefill
        rounds plus one fused decode round per new token."""
        rounds = float(getattr(req, "max_new_tokens", 1))
        chunk = getattr(self.rt, "prefill_chunk_tokens", 0)
        toks = getattr(req, "tokens", None)
        if chunk and toks is not None:
            rounds += -(-len(toks) // chunk)
        return rounds

    def service_estimate(self, req) -> float:
        """This request's own unavoidable service time (caller clock).  A
        parked request only owes its REMAINING decode rounds — its KV is
        resident, resume costs no prefill."""
        entry = self.parked.get(getattr(req, "rid", -1))
        if entry is not None:
            remaining = (getattr(req, "max_new_tokens", 1)
                         - len(entry.emitted))
            return max(0, remaining) * self._round_dt
        return self._rounds(req) * self._round_dt

    def slack(self, req, now: float) -> float:
        """Deadline budget left AFTER the request's own service time.
        ``inf`` for deadline-less requests (never shed on slack)."""
        deadline = getattr(req, "deadline_s", 0.0)
        if not deadline:
            return _INF
        return deadline - now - self.service_estimate(req)

    def wait_estimate(self, now: float, position: int = 0) -> float:
        """Expected queue wait before the request at slack-order
        ``position`` starts, in the caller's clock.  Under SDF the head
        does NOT wait out the whole queue — it takes the next slot that
        frees (~one slot-turn of the observed service time); position k
        waits k more slot-turns.  This is what makes OFFLOAD verdicts
        position-aware: the head is rescued locally (by waiting or by
        preemption), the deep tail is forwarded while a peer can still
        make its deadline."""
        turns = (position + 1) / max(1, self.rt.total_slots())
        return turns * self._svc_logical

    def slot_slack(self, slot, now: float) -> float:
        """Victim-selection slack of a LIVE decode slot: budget left after
        its remaining decode rounds.  Deadline-less slots are infinitely
        lazy — the preferred preemption victims."""
        deadline = getattr(slot.req, "deadline_s", 0.0)
        if not deadline:
            return _INF
        return deadline - now - self.remaining_estimate(slot)

    def remaining_estimate(self, slot) -> float:
        remaining = slot.req.max_new_tokens - len(slot.emitted)
        return max(0, remaining) * self._round_dt

    # -- the StrictestDeadlineFirst pass ----------------------------------
    def order(self, now: float) -> None:
        """Reorder pending admissions: strictest (least-slack) deadline
        first; deadline-less requests keep FIFO order among themselves at
        the back."""
        if not self.active:
            return
        self.rt.composer.reorder(
            lambda it: (self.slack(it.payload, now), it.enqueued_s))

    def shed(self, now: float) -> List[Tuple[QueuedItem, Outcome]]:
        """Walk the queue once and shed, with verdicts:

        * ``DEADLINE_MISSED`` — negative slack (cannot finish anywhere);
        * ``OFFLOAD`` — positive slack the local wait would burn (parked
          requests are exempt: their KV is local, forwarding loses it);
        * ``CONGESTION`` — survivors beyond ``congestion_factor × slots``,
          laziest first.

        Returns (item, verdict) pairs; the ENGINE releases parked blocks
        / session pins and builds the ``AdmissionReject`` records.
        """
        if not self.active or not len(self.rt.composer):
            return []
        survivors: List[Tuple[float, float]] = []

        def pred(item: QueuedItem) -> Optional[Outcome]:
            sl = self.slack(item.payload, now)
            if sl < 0.0:
                return Outcome.DEADLINE_MISSED
            # the caller reorders BEFORE shedding, so the walk runs in
            # slack order and len(survivors) is this item's queue
            # position.  Exemptions from OFFLOAD: parked requests (their
            # KV is local — forwarding loses it) and, when preemption is
            # on, the HEAD (position 0): parking a lazy victim is its
            # local rescue path, and preemption frees one slot per step —
            # exactly one head's worth.
            if sl != _INF and item.rid not in self.parked \
                    and not (self.preempt and not survivors) \
                    and self.wait_estimate(now, len(survivors)) > sl:
                return Outcome.OFFLOAD
            survivors.append((sl, item.enqueued_s))
            return None

        dropped = self.rt.composer.shed(pred)
        bound = int(self.congestion_factor
                    * max(1, self.rt.total_slots()))
        if len(survivors) > bound:
            cutoff = sorted(survivors)[bound - 1]

            def congest(item: QueuedItem) -> Optional[Outcome]:
                key = (self.slack(item.payload, now), item.enqueued_s)
                return Outcome.CONGESTION if key > cutoff else None

            dropped.extend(self.rt.composer.shed(congest))
        for _, verdict in dropped:
            self._count(verdict)
        return dropped

    # -- preemption bookkeeping (mechanism lives in the engine) -----------
    def pick_victim(self, urgent_slack: float, candidates) -> Optional[Any]:
        """Choose the laziest live slot worth parking for an urgent head.
        A victim must (a) be strictly lazier than the urgent request and
        (b) afford the round trip — its slack must cover the urgent
        request's slack plus its own remaining work (deadline-less slots
        always qualify).  Prefers the laziest, then the longest-remaining
        (frees capacity for longest).  ``candidates`` yields
        ``(slot_slack, remaining_estimate, token)`` triples."""
        best = None
        for vslack, vrem, token in candidates:
            if vslack <= urgent_slack:
                continue
            if vslack != _INF and vslack < urgent_slack + vrem:
                continue
            key = (vslack, vrem)
            if best is None or key > best[0]:
                best = (key, token)
        return None if best is None else best[1]

    def note_park(self, entry: ParkedEntry) -> None:
        self.parked[entry.req.rid] = entry
        self.preemptions += 1

    def pop_parked(self, rid: int) -> Optional[ParkedEntry]:
        return self.parked.pop(rid, None)

    def parked_group(self, rid: int) -> Optional[int]:
        entry = self.parked.get(rid)
        return None if entry is None else entry.group

    def note_resume(self) -> None:
        self.resumes += 1

    def note_admit(self, n: int = 1) -> None:
        """Count ADMIT verdicts (resumed re-admissions included — the
        engine's ``admitted`` tally already covers them)."""
        if self.active and n > 0:
            self.verdicts[Outcome.ADMIT.value] = \
                self.verdicts.get(Outcome.ADMIT.value, 0) + n
