"""BS / MF batch composition (§3.1 operators, Eq. 5).

* BS: group up to ``bs`` same-service requests per batch.
* MF (multi-frame): for frequency tasks, take an IDENTICAL number of frames
  (``mf``) from each of ``inter_request_count = floor(bs / mf)`` concurrent
  homogeneous streams, filling the batch even when single streams are
  bursty/uneven — the request-level trick that lifts GPU utilization.

Both composers implement the single ``Composer`` protocol: ``add`` /
``push_front`` / ``__len__`` / ``compose(*, limit, now, max_wait_s)``.
``compose`` is **capacity-aware** (``limit=k`` fills at most ``k`` items so
the continuous-batching engine can top up only the decode slots that are
actually free, instead of composing a full ``bs`` batch behind a barrier)
and takes the clock uniformly — BS simply ignores ``now``/``max_wait_s``,
so the engine and the simulator never special-case the composer family.
``push_front`` returns an item to the head of its queue (used when sticky
DP routing finds the session's replica group full).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import (Any, Callable, Deque, Dict, List, Optional, Protocol,
                    Tuple, runtime_checkable)

from repro.core.allocator import ParallelPlan


@dataclasses.dataclass
class QueuedItem:
    payload: Any                 # tokens / frame embedding reference
    stream: int = 0              # stream/session id (MF groups by stream)
    enqueued_s: float = 0.0
    rid: int = 0


def _prefill_cost(item: QueuedItem) -> int:
    """Prompt tokens one queued item brings to the chunked-prefill phase
    (0 for payloads without a token prompt, e.g. simulator stand-ins)."""
    toks = getattr(item.payload, "tokens", None)
    return 0 if toks is None else len(toks)


@dataclasses.dataclass
class ComposedBatch:
    items: List[QueuedItem]
    mf: int                      # frames actually taken per stream (max)
    streams: Tuple[int, ...]     # which streams contributed
    frames_per_stream: Dict[int, int] = dataclasses.field(
        default_factory=dict)    # actual frames taken from each stream

    @property
    def size(self) -> int:
        return len(self.items)


@runtime_checkable
class Composer(Protocol):
    """What the slot engine requires of a batch composer.  One signature
    for every family: BS ignores the clock arguments, MF uses them for
    its overdue partial-flush semantics."""

    def add(self, item: QueuedItem) -> None: ...

    def push_front(self, item: QueuedItem) -> None: ...

    def __len__(self) -> int: ...

    def compose(self, *, limit: Optional[int] = None, now: float = 0.0,
                max_wait_s: float = float("inf")
                ) -> Optional[ComposedBatch]: ...

    def pending_prefill_tokens(self) -> int: ...

    # admission-control surface (serving/admission.py): the controller
    # reorders pending items by deadline slack, sheds the doomed ones with
    # explicit verdicts, and peeks the most urgent head to decide whether
    # preempting a live slot is worth it.
    def peek(self) -> Optional[QueuedItem]: ...

    def reorder(self, key: Callable[[QueuedItem], Any]) -> None: ...

    def shed(self, pred: Callable[[QueuedItem], Optional[Any]]
             ) -> List[Tuple[QueuedItem, Any]]: ...


def _frame_counts(items: List[QueuedItem]) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for it in items:
        counts[it.stream] = counts.get(it.stream, 0) + 1
    return counts


class BSComposer:
    """Latency tasks: plain FIFO batching up to ``bs`` (or ``limit``)."""

    def __init__(self, plan: ParallelPlan):
        self.plan = plan
        self.queue: Deque[QueuedItem] = collections.deque()

    def add(self, item: QueuedItem) -> None:
        self.queue.append(item)

    def push_front(self, item: QueuedItem) -> None:
        self.queue.appendleft(item)

    def __len__(self) -> int:
        return len(self.queue)

    def pending_prefill_tokens(self) -> int:
        """Queued prompt tokens — the chunked-prefill backlog the engine
        folds into its queue-time estimate."""
        return sum(_prefill_cost(it) for it in self.queue)

    def peek(self) -> Optional[QueuedItem]:
        return self.queue[0] if self.queue else None

    def reorder(self, key: Callable[[QueuedItem], Any]) -> None:
        """Re-sort the whole queue (slack-ordered admission); compose then
        pops in the new order."""
        self.queue = collections.deque(sorted(self.queue, key=key))

    def shed(self, pred: Callable[[QueuedItem], Optional[Any]]
             ) -> List[Tuple[QueuedItem, Any]]:
        """Drop every queued item for which ``pred`` returns a verdict
        (non-None); returns the (item, verdict) pairs in queue order."""
        kept: Deque[QueuedItem] = collections.deque()
        dropped: List[Tuple[QueuedItem, Any]] = []
        for it in self.queue:
            v = pred(it)
            if v is None:
                kept.append(it)
            else:
                dropped.append((it, v))
        self.queue = kept
        return dropped

    def compose(self, *, limit: Optional[int] = None, now: float = 0.0,
                max_wait_s: float = float("inf")
                ) -> Optional[ComposedBatch]:
        cap = self.plan.bs if limit is None else min(self.plan.bs, limit)
        if not self.queue or cap <= 0:
            return None
        items = []
        while self.queue and len(items) < cap:
            items.append(self.queue.popleft())
        counts = _frame_counts(items)
        return ComposedBatch(items=items, mf=max(counts.values()),
                             streams=tuple(counts),
                             frames_per_stream=counts)


class MFComposer:
    """Frequency tasks: per-stream queues; a batch takes exactly ``mf``
    frames from each of up to ``inter_request_count`` streams (Eq. 5).
    Falls back to fewer streams / partial mf when starved so frames never
    wait past their latency budget.  The composed batch reports the frames
    ACTUALLY taken per stream (a starved partial flush takes fewer than the
    plan's ``mf``)."""

    def __init__(self, plan: ParallelPlan):
        self.plan = plan
        self.streams: Dict[int, Deque[QueuedItem]] = {}
        self._key: Optional[Callable[[QueuedItem], Any]] = None

    def add(self, item: QueuedItem) -> None:
        self.streams.setdefault(item.stream, collections.deque()).append(item)

    def push_front(self, item: QueuedItem) -> None:
        self.streams.setdefault(item.stream,
                                collections.deque()).appendleft(item)

    def __len__(self) -> int:
        return sum(len(q) for q in self.streams.values())

    def pending_prefill_tokens(self) -> int:
        return sum(_prefill_cost(it) for q in self.streams.values()
                   for it in q)

    def peek(self) -> Optional[QueuedItem]:
        heads = [q[0] for q in self.streams.values() if q]
        if not heads:
            return None
        key = self._key or (lambda it: it.enqueued_s)
        return min(heads, key=key)

    def reorder(self, key: Callable[[QueuedItem], Any]) -> None:
        """MF keeps frames in per-stream FIFO order (frames of one stream
        are totally ordered); slack ordering applies ACROSS streams — the
        stored key decides which streams a composed batch draws from
        first."""
        self._key = key

    def shed(self, pred: Callable[[QueuedItem], Optional[Any]]
             ) -> List[Tuple[QueuedItem, Any]]:
        dropped: List[Tuple[QueuedItem, Any]] = []
        for s in list(self.streams):
            kept: Deque[QueuedItem] = collections.deque()
            for it in self.streams[s]:
                v = pred(it)
                if v is None:
                    kept.append(it)
                else:
                    dropped.append((it, v))
            if kept:
                self.streams[s] = kept
            else:
                del self.streams[s]
        return dropped

    def compose(self, *, limit: Optional[int] = None, now: float = 0.0,
                max_wait_s: float = float("inf")
                ) -> Optional[ComposedBatch]:
        mf = max(1, self.plan.mf)
        irc = self.plan.inter_request_count
        cap = self.plan.bs if limit is None else min(self.plan.bs, limit)
        if cap <= 0:
            return None
        if cap < mf:             # few free slots: admit a partial mf rather
            mf = cap             # than stalling admission entirely
        irc = max(1, min(irc, cap // mf))
        ready = [s for s, q in self.streams.items() if len(q) >= mf]
        overdue = any(q and now - q[0].enqueued_s >= max_wait_s
                      for q in self.streams.values())
        if len(ready) < 1 and not overdue:
            return None
        if not ready and overdue:
            # partial-mf flush: take whatever the oldest streams have
            ready = sorted((s for s, q in self.streams.items() if q),
                           key=lambda s: self.streams[s][0].enqueued_s)
        elif self._key is not None:
            # slack-ordered admission: most urgent stream head first
            ready.sort(key=lambda s: self._key(self.streams[s][0]))
        take_streams = ready[:irc]
        items: List[QueuedItem] = []
        budget = cap
        for s in take_streams:
            q = self.streams[s]
            take = min(mf, len(q), budget)
            for _ in range(take):
                items.append(q.popleft())
            budget -= take
            if budget <= 0:
                break
        for s in list(self.streams):
            if not self.streams[s]:
                del self.streams[s]
        if not items:
            return None
        counts = _frame_counts(items)
        return ComposedBatch(items=items, mf=max(counts.values()),
                             streams=tuple(s for s in take_streams
                                           if s in counts),
                             frames_per_stream=counts)


def make_composer(plan: ParallelPlan) -> Composer:
    from repro.core.categories import Sensitivity
    if plan.category.sensitivity == Sensitivity.FREQUENCY and plan.mf > 1:
        return MFComposer(plan)
    return BSComposer(plan)
