"""BS / MF batch composition (§3.1 operators, Eq. 5).

* BS: group up to ``bs`` same-service requests per batch.
* MF (multi-frame): for frequency tasks, take an IDENTICAL number of frames
  (``mf``) from each of ``inter_request_count = floor(bs / mf)`` concurrent
  homogeneous streams, filling the batch even when single streams are
  bursty/uneven — the request-level trick that lifts GPU utilization.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.core.allocator import ParallelPlan


@dataclasses.dataclass
class QueuedItem:
    payload: Any                 # tokens / frame embedding reference
    stream: int = 0              # stream/session id (MF groups by stream)
    enqueued_s: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class ComposedBatch:
    items: List[QueuedItem]
    mf: int                      # frames taken per stream
    streams: Tuple[int, ...]     # which streams contributed

    @property
    def size(self) -> int:
        return len(self.items)


class BSComposer:
    """Latency tasks: plain FIFO batching up to ``bs``."""

    def __init__(self, plan: ParallelPlan):
        self.plan = plan
        self.queue: Deque[QueuedItem] = collections.deque()

    def add(self, item: QueuedItem) -> None:
        self.queue.append(item)

    def __len__(self) -> int:
        return len(self.queue)

    def compose(self) -> Optional[ComposedBatch]:
        if not self.queue:
            return None
        items = []
        while self.queue and len(items) < self.plan.bs:
            items.append(self.queue.popleft())
        return ComposedBatch(items=items, mf=1,
                             streams=tuple({i.stream for i in items}))


class MFComposer:
    """Frequency tasks: per-stream queues; a batch takes exactly ``mf``
    frames from each of up to ``inter_request_count`` streams (Eq. 5).
    Falls back to fewer streams / partial mf when starved so frames never
    wait past their latency budget."""

    def __init__(self, plan: ParallelPlan):
        self.plan = plan
        self.streams: Dict[int, Deque[QueuedItem]] = {}

    def add(self, item: QueuedItem) -> None:
        self.streams.setdefault(item.stream, collections.deque()).append(item)

    def __len__(self) -> int:
        return sum(len(q) for q in self.streams.values())

    def compose(self, *, now: float = 0.0,
                max_wait_s: float = float("inf")) -> Optional[ComposedBatch]:
        mf = max(1, self.plan.mf)
        irc = self.plan.inter_request_count
        ready = [s for s, q in self.streams.items() if len(q) >= mf]
        overdue = any(q and now - q[0].enqueued_s >= max_wait_s
                      for q in self.streams.values())
        if len(ready) < 1 and not overdue:
            return None
        if not ready and overdue:
            # partial-mf flush: take whatever the oldest streams have
            ready = sorted((s for s, q in self.streams.items() if q),
                           key=lambda s: self.streams[s][0].enqueued_s)
        take_streams = ready[:irc]
        items: List[QueuedItem] = []
        for s in take_streams:
            q = self.streams[s]
            for _ in range(min(mf, len(q))):
                items.append(q.popleft())
        for s in list(self.streams):
            if not self.streams[s]:
                del self.streams[s]
        if not items:
            return None
        return ComposedBatch(items=items, mf=mf, streams=tuple(take_streams))


def make_composer(plan: ParallelPlan):
    from repro.core.categories import Sensitivity
    if plan.category.sensitivity == Sensitivity.FREQUENCY and plan.mf > 1:
        return MFComposer(plan)
    return BSComposer(plan)
