"""Paged KV arena: fixed-capacity, block-table cache store for the slot
engine.

The dense cache path (``kvcache.merge`` / ``select_slots``) re-materializes
the whole live batch on every admission and changes the cache's batch axis
whenever the live count changes — so each admission copies O(live cache)
bytes and each batch-size change retraces the fused decode step under XLA.
``KVArena`` replaces that with an allocator-shaped API sized once from the
``ParallelPlan``:

* the **token axis is paged**: every unbounded KV sequence axis is stored
  as physical blocks of ``block_size`` tokens in a shared pool, and each
  slot owns a row of a ``(capacity, blocks_per_slot)`` **block table**
  mapping logical block -> physical block (a reserved trash block absorbs
  writes from unoccupied slots, so the fused step needs no branches);
* **admission writes pages in place** (``alloc`` + ``write_prefill``
  scatter exactly the new request's pages and per-slot state — the live
  batch is never touched);
* **eviction is a free-list operation** (``free`` returns the slot's
  blocks; no device work at all);
* **blocks are shareable across slots** (prefix cache): ``alloc`` can
  stitch already-resident blocks into a new slot's table
  (``shared=...``), per-block refcounts keep them alive across source
  evictions, ``register``/``unregister`` let a prefix index freeze
  blocks (writers ``cow_block`` first — copy-on-write on divergence),
  and ref-0 cached blocks park on an LRU the allocator reclaims before
  ever failing;
* the decode step always runs at the full static shape ``(capacity, ...)``
  with an occupancy mask, so it compiles exactly once per service.

Cache pytrees keep the shape convention documented in ``kvcache``:
``ndim >= 2`` leaves are ``(layers, batch, ...)`` batched state, small
integer leaves are sequence lengths.  The arena classifies each leaf ONCE
at construction by probing ``init_cache`` at two ``max_len`` values
(``jax.eval_shape`` — no allocation): axes that grow with ``max_len`` are
sequence axes and get paged; everything else (SSM/conv state, encoder
cross-KV, saturated sliding-window rings) is fixed-size per-slot state
held at ``(layers, capacity, ...)``.  This makes the arena family-agnostic
across all six model families.
"""
from __future__ import annotations

import functools
import math
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant import QuantPages, dequantize, quantize

Cache = Any  # pytree of arrays

_LEN, _PAGED, _STATE = "len", "paged", "state"

VALID_KV_DTYPES = ("bf16", "int8")


def _is_quant(pool) -> bool:
    return isinstance(pool, QuantPages)


def _is_len_leaf(shape: Tuple[int, ...], dtype) -> bool:
    return len(shape) <= 1 and jnp.issubdtype(dtype, jnp.integer)


class KVArena:
    """Fixed-capacity paged cache arena for one DP replica group.

    Host-side bookkeeping (free lists, block tables, occupancy) is plain
    numpy; device state is three pytrees of fixed-shape arrays — ``pages``
    (block pools for sequence leaves), ``state`` (per-slot fixed-size
    leaves) and ``lens`` (``(capacity,)`` int32) — threaded functionally
    through the jitted decode step via the pure helpers below.
    """

    def __init__(self, cfg, init_cache: Callable, *, capacity: int,
                 max_seq_len: int, block_size: int = 32,
                 pool_blocks: Optional[int] = None, dtype=None,
                 kv_dtype: str = "bf16"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if kv_dtype not in VALID_KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {VALID_KV_DTYPES}, "
                             f"got {kv_dtype!r}")
        # "bf16" = keep the family's native KV dtype (the model config's
        # compute dtype — f32 in the toy configs); "int8" = quantized block
        # format: floating paged leaves become QuantPages pools (int8
        # values + per-token-per-head f32 scales travelling with the
        # blocks).  Fixed per-slot STATE leaves (SSM conv/SSD state,
        # encoder cross-KV, saturated ring windows) are never quantized.
        self.kv_dtype = kv_dtype
        self.cfg = cfg
        self.capacity = int(capacity)
        self.block_size = int(block_size)
        self.blocks_per_slot = max(1, math.ceil(max_seq_len / block_size))
        self.slot_tokens = self.blocks_per_slot * self.block_size  # S_max
        self.pool_blocks = (self.capacity * self.blocks_per_slot
                            if pool_blocks is None else int(pool_blocks))
        if self.pool_blocks < self.blocks_per_slot:
            raise ValueError("pool smaller than one slot's block budget")
        self.trash_block = self.pool_blocks       # reserved garbage block

        # -- classify the family's cache layout by probing init_cache ----
        probe = lambda s: jax.eval_shape(
            lambda: init_cache(cfg, 1, s, dtype) if dtype is not None
            else init_cache(cfg, 1, s))
        lo, hi = probe(self.slot_tokens), probe(self.slot_tokens
                                               + self.block_size)
        lo_leaves, self._treedef = jax.tree.flatten(lo)
        hi_leaves = jax.tree.leaves(hi)
        self._tags: List[str] = []
        self._paged_shapes: List[Tuple[int, ...]] = []
        self._state_shapes: List[Tuple[int, ...]] = []
        self._dtypes: List[Any] = []
        for a, b in zip(lo_leaves, hi_leaves):
            self._dtypes.append(a.dtype)
            if _is_len_leaf(a.shape, a.dtype):
                self._tags.append(_LEN)
                continue
            grown = [d for d in range(a.ndim) if a.shape[d] != b.shape[d]]
            if not grown:
                if a.ndim < 2 or a.shape[1] != 1:
                    raise ValueError(
                        f"state leaf {a.shape} lacks a batch axis at 1")
                self._tags.append(_STATE)
                self._state_shapes.append(a.shape)
            else:
                if grown != [2] or a.ndim < 3 or a.shape[1] != 1:
                    raise ValueError(
                        f"paged leaf must grow only along axis 2 "
                        f"(layers, batch, seq, ...); got {a.shape} vs "
                        f"{b.shape}")
                if a.shape[2] != self.slot_tokens:
                    raise ValueError(
                        f"seq axis {a.shape[2]} != arena slot_tokens "
                        f"{self.slot_tokens}")
                self._tags.append(_PAGED)
                self._paged_shapes.append(a.shape)

        # -- device state --------------------------------------------------
        P1 = self.pool_blocks + 1                 # +1 trash block
        self.pages: List[Any] = []
        self._quantized: List[bool] = []          # per paged leaf
        self.state: List[jnp.ndarray] = []
        for i, tag in enumerate(self._tags):
            if tag == _PAGED:
                A0, _, _, *rest = lo_leaves[i].shape
                quant = (self.kv_dtype == "int8" and len(rest) >= 1
                         and jnp.issubdtype(self._dtypes[i], jnp.floating))
                self._quantized.append(quant)
                if quant:
                    self.pages.append(QuantPages(
                        jnp.zeros((A0, P1, self.block_size, *rest),
                                  jnp.int8),
                        jnp.zeros((A0, P1, self.block_size, *rest[:-1]),
                                  jnp.float32)))
                else:
                    self.pages.append(jnp.zeros(
                        (A0, P1, self.block_size, *rest), self._dtypes[i]))
            elif tag == _STATE:
                A0, _, *rest = lo_leaves[i].shape
                self.state.append(jnp.zeros((A0, self.capacity, *rest),
                                            self._dtypes[i]))
        self.lens = jnp.zeros((self.capacity,), jnp.int32)

        # -- host bookkeeping ----------------------------------------------
        self._block_tables = np.full(
            (self.capacity, self.blocks_per_slot), self.trash_block,
            np.int32)
        self._free_slots: List[int] = list(range(self.capacity))
        self._free_blocks: List[int] = list(range(self.pool_blocks))
        self._slot_blocks = {}
        self._occ = np.zeros((self.capacity,), bool)
        self._write_fns: Dict[int, Callable] = {}
        self._tables_dev: Optional[jnp.ndarray] = None
        self._occ_dev: Optional[jnp.ndarray] = None

        # -- cross-slot block sharing (prefix cache) -----------------------
        # A physical block may back several slots' block-table rows (shared
        # prompt prefixes) and/or be retained by a prefix index after every
        # referencing slot died.  ``_block_refs`` counts live slot
        # references; ``_cached`` marks blocks registered by a prefix index
        # (their content is immutable — any write COWs first); ref-0 cached
        # blocks park in ``_idle_cached`` (an LRU by last release) and are
        # reclaimed before the allocator ever fails, via ``evict_hook`` so
        # the index drops its entries.
        self._block_refs = np.zeros((self.pool_blocks,), np.int32)
        self._cached: set = set()
        self._idle_cached: "OrderedDict[int, None]" = OrderedDict()
        self.evict_hook: Optional[Callable[[int], None]] = None
        self.cache_retention: Optional[int] = None  # max idle cached blocks
        self.cached_evictions = 0     # idle cached blocks reclaimed
        self.parks = 0                # preemption block-table parks
        self.parked_blocks = 0        # blocks currently held by parked
        #                               requests (admission headroom lost
        #                               to frozen-but-resumable KV)
        self.cow_copies = 0           # copy-on-write block copies
        self.cow_calls = 0            # jitted COW dispatches (batching
        #                               coalesces a wave's copies into one)
        self._cow_many_fns: Dict[int, Callable] = {}

        # bytes one cache token occupies across all paged leaves, and the
        # fixed per-slot state footprint (allocator-style accounting).  A
        # quantized leaf counts 1 byte per value plus its f32 per-row scale
        self.token_bytes = 0
        paged_dtypes = [self._dtypes[i] for i, t in enumerate(self._tags)
                        if t == _PAGED]
        self._paged_dtypes = paged_dtypes
        for s, d, q in zip(self._paged_shapes, paged_dtypes,
                           self._quantized):
            if q:
                self.token_bytes += int(np.prod([s[0], *s[3:]]))      # int8
                self.token_bytes += int(np.prod([s[0], *s[3:-1]])) * 4
            else:
                self.token_bytes += (int(np.prod([s[0], *s[3:]]))
                                     * np.dtype(d).itemsize)
        self.state_slot_bytes = sum(
            int(np.prod([s[0], *s[2:]])) * np.dtype(d).itemsize
            for s, d in zip(self._state_shapes,
                            (self._dtypes[i] for i, t in
                             enumerate(self._tags) if t == _STATE)))

    # ------------------------------------------------------------------
    # allocator surface
    # ------------------------------------------------------------------
    def blocks_for(self, total_tokens: int) -> int:
        return max(1, math.ceil(total_tokens / self.block_size))

    @property
    def free_capacity(self) -> int:
        """Blocks the allocator can hand out without failing: the free
        list plus every reclaimable (ref-0 cached) block."""
        return len(self._free_blocks) + len(self._idle_cached)

    def can_alloc(self, total_tokens: int, *, shared: Sequence[int] = (),
                  reserve: int = 0) -> bool:
        """Admission feasibility.  ``shared`` lists the cached blocks a
        prefix hit would stitch in (they reduce the fresh-block demand,
        but idle ones must be EXCLUDED from the reclaimable supply — the
        hit revives them); ``reserve`` asks for extra claimable headroom
        (e.g. the divergence-COW copy a partial-tail share will need)."""
        shared = list(shared)
        idle_shared = sum(1 for b in shared if b in self._idle_cached)
        claimable = (len(self._free_blocks) + len(self._idle_cached)
                     - idle_shared)
        return (bool(self._free_slots)
                and (self.blocks_for(total_tokens) - len(shared) + reserve
                     <= claimable)
                and total_tokens <= self.slot_tokens)

    def _reclaim_lru_block(self) -> None:
        """Evict the least-recently-released idle cached block back to the
        free list.  The append happens BEFORE the hook fires: the hook's
        ``unregister`` calls (subtree drops) must see this block as
        already freed, or they would double-append it."""
        blk, _ = self._idle_cached.popitem(last=False)
        self._cached.discard(blk)
        self.cached_evictions += 1
        self._free_blocks.append(blk)
        if self.evict_hook is not None:
            self.evict_hook(blk)

    def _claim_blocks(self, n: int) -> List[int]:
        """Pop ``n`` blocks from the free list, reclaiming idle cached
        blocks in LRU order when it runs short (``evict_hook`` lets the
        prefix index drop the evicted block's entries first)."""
        while len(self._free_blocks) < n and self._idle_cached:
            self._reclaim_lru_block()
        if len(self._free_blocks) < n:
            raise RuntimeError("arena out of blocks")
        return [self._free_blocks.pop(0) for _ in range(n)]

    def alloc(self, total_tokens: int, slot: Optional[int] = None, *,
              shared: Sequence[int] = ()) -> int:
        """Claim a slot and its token blocks for a request whose lifetime
        needs ``total_tokens`` (prompt + generation budget).  ``shared``
        stitches already-resident physical blocks (a cached prompt prefix)
        into the FRONT of the slot's block table instead of claiming fresh
        blocks for those positions — each one's refcount rises and idle
        cached blocks are revived off the LRU."""
        if total_tokens > self.slot_tokens:
            raise ValueError(
                f"request needs {total_tokens} tokens > arena slot budget "
                f"{self.slot_tokens} (raise max_seq_len)")
        n = self.blocks_for(total_tokens)
        shared = list(shared)
        if len(shared) > n:
            raise ValueError(
                f"{len(shared)} shared prefix blocks exceed the request's "
                f"{n}-block budget")
        # incref the shared prefix FIRST so a same-call reclaim sweep can
        # never evict a block the hit is about to use
        for b in shared:
            if self._block_refs[b] == 0:
                self._idle_cached.pop(b, None)
            self._block_refs[b] += 1
        try:
            fresh = self._claim_blocks(n - len(shared))
        except RuntimeError:
            for b in shared:          # undo the increfs; caller requeues
                self._release_block(b)
            raise
        if slot is None:
            if not self._free_slots:
                for b in shared:
                    self._release_block(b)
                self._free_blocks.extend(fresh)
                raise RuntimeError("arena out of slots")
            slot = self._free_slots.pop(0)
        else:
            self._free_slots.remove(slot)
        for b in fresh:
            self._block_refs[b] = 1
        blocks = shared + fresh
        self._slot_blocks[slot] = blocks
        row = np.full((self.blocks_per_slot,), self.trash_block, np.int32)
        row[:n] = blocks
        self._block_tables[slot] = row
        self._occ[slot] = True
        self._tables_dev = self._occ_dev = None
        return slot

    def reset_len(self, slot: int) -> None:
        """Zero a slot's device-side length.  Chunked admissions must call
        this after ``alloc``: the first chunk reads its start offset from
        ``lens`` (one-shot ``write_prefill`` overwrites it, chunk writes
        only advance it — a recycled slot would otherwise resume at the
        previous tenant's length)."""
        self.set_len(slot, 0)

    def set_len(self, slot: int, n: int) -> None:
        """Set a slot's device-side length — a prefix-cache hit admits with
        ``lens[slot] = hit_tokens`` so chunked prefill resumes past the
        shared prefix."""
        self.lens = self.lens.at[slot].set(n)

    def _release_block(self, block: int) -> None:
        """Drop one slot reference; a ref-0 block parks on the cached LRU
        if a prefix index still wants it, else returns to the free list."""
        self._block_refs[block] -= 1
        if self._block_refs[block] > 0:
            return
        self._block_refs[block] = 0
        if block in self._cached:
            self._idle_cached.pop(block, None)
            self._idle_cached[block] = None       # most-recently released
        else:
            self._free_blocks.append(block)

    def free(self, slot: int) -> None:
        """Release a slot: pure free-list bookkeeping, zero device work.
        Blocks shared with other slots (or retained by a prefix index)
        survive; only the last reference returns a block to circulation."""
        if not self._occ[slot]:
            return
        for b in self._slot_blocks.pop(slot):
            self._release_block(b)
        self._block_tables[slot] = self.trash_block
        self._occ[slot] = False
        self._free_slots.append(slot)
        self._tables_dev = self._occ_dev = None
        self._enforce_retention()

    # ------------------------------------------------------------------
    # preemption surface: block-table parking
    # ------------------------------------------------------------------
    @property
    def parkable(self) -> bool:
        """Preemption by parking freezes only the slot's BLOCKS; per-slot
        state leaves (SSM conv/recurrent state, ring windows) live in
        slot-indexed buffers that the next tenant overwrites, so layouts
        that carry any cannot park."""
        return not self._state_shapes

    def park(self, slot: int) -> List[int]:
        """Freeze a live slot's blocks and free the SLOT without releasing
        the blocks: the caller now owns one reference per block (exactly
        the references the slot held) and the physical KV stays resident.
        Resume hands them back through ``alloc(total, shared=blocks)``
        (which re-increfs) followed by ``release_parked`` (dropping the
        parked hold) — net refcounts unchanged, bit-identical content."""
        if not self._occ[slot]:
            raise ValueError(f"slot {slot} is not occupied")
        if not self.parkable:
            raise ValueError(
                "arena carries per-slot state leaves; parking would "
                "destroy them on slot reuse")
        blocks = self._slot_blocks.pop(slot)
        self._block_tables[slot] = self.trash_block
        self._occ[slot] = False
        self._free_slots.append(slot)
        self._tables_dev = self._occ_dev = None
        self.parks += 1
        self.parked_blocks += len(blocks)
        return blocks

    def release_parked(self, blocks: Sequence[int]) -> None:
        """Drop a parked hold — after a resume's ``alloc(shared=blocks)``
        re-increfed them, or to abandon an expired parked request (then
        cached blocks fall to the idle LRU, private ones to the free
        list)."""
        for b in blocks:
            self._release_block(b)
        self.parked_blocks -= len(blocks)
        self._enforce_retention()

    # ------------------------------------------------------------------
    # prefix-cache surface: registration, retention, copy-on-write
    # ------------------------------------------------------------------
    def register(self, block: int) -> None:
        """Mark a block as held by a prefix index: its content is frozen
        (writers COW) and it outlives its slots, parked on the LRU until
        reclaimed or re-shared."""
        self._cached.add(block)

    def unregister(self, block: int) -> None:
        """Prefix index dropped its entry: an idle block goes straight
        back to the free list, a live one merely loses immutability once
        its refs drain.  (Every indexed ref-0 block is on the idle list —
        a ref-0 uncached block is already free — so this is O(1).)"""
        self._cached.discard(block)
        if block in self._idle_cached:
            del self._idle_cached[block]
            self._free_blocks.append(block)

    def _enforce_retention(self) -> None:
        """Cap the idle cached pool at ``cache_retention`` blocks (the
        category knob: latency plans keep a bounded prefix cache,
        frequency plans retain aggressively)."""
        if self.cache_retention is None:
            return
        while len(self._idle_cached) > self.cache_retention:
            self._reclaim_lru_block()

    def block_ref(self, block: int) -> int:
        return int(self._block_refs[block])

    def is_cached(self, block: int) -> bool:
        return block in self._cached

    def cow_block(self, slot: int, logical: int) -> bool:
        """Copy-on-write: give ``slot`` a private copy of its ``logical``-th
        block if the physical block is shared with another slot or frozen
        by a prefix index.  Returns True when a copy happened (one block of
        device copy; the table row changes, so the device table re-uploads
        on next use)."""
        return self.cow_blocks([(slot, logical)]) > 0

    def cow_blocks(self, pairs: Sequence[Tuple[int, int]]) -> int:
        """Batched copy-on-write: coalesce several pending single-block
        COWs — e.g. the divergence copies of one admission wave whose
        members share a prompt template — into ONE jitted gather/scatter
        over (srcs, dsts) index vectors instead of one jit dispatch per
        block.  ``pairs`` lists (slot, logical) targets; blocks a slot
        already owns exclusively are skipped.  The copy vectors pad to the
        next power of two (padding copies the trash block onto itself) so
        the dispatch count stays O(log capacity) shapes, not one per wave
        size.  Returns the number of real blocks copied."""
        # phase 1 — decide, without mutating: which pairs actually need a
        # private copy (two sharers of the same source both do)
        needed: List[Tuple[int, int, int]] = []   # (slot, logical, phys)
        for slot, logical in pairs:
            phys = int(self._block_tables[slot][logical])
            if phys == self.trash_block:
                raise ValueError(f"slot {slot} logical block {logical} is "
                                 f"unallocated")
            if self._block_refs[phys] <= 1 and phys not in self._cached:
                continue
            needed.append((slot, logical, phys))
        if not needed:
            return 0
        # phase 2 — claim EVERY destination up front, before any table
        # mutation: if the arena is exhausted this raises with all
        # bookkeeping still consistent (the sources have live slot refs,
        # so the claim sweep can never reclaim them)
        fresh_blocks = self._claim_blocks(len(needed))
        todo: List[Tuple[int, int]] = []          # (phys, fresh)
        for (slot, logical, phys), fresh in zip(needed, fresh_blocks):
            self._block_refs[fresh] = 1
            blocks = self._slot_blocks[slot]
            blocks[blocks.index(phys)] = fresh
            self._block_tables[slot][logical] = fresh
            todo.append((phys, fresh))
        n = 1
        while n < len(todo):
            n *= 2
        src = np.full((n,), self.trash_block, np.int32)
        dst = np.full((n,), self.trash_block, np.int32)
        for i, (s, d) in enumerate(todo):
            src[i], dst[i] = s, d
        fn = self._cow_many_fns.get(n)
        if fn is None:
            def _copy(pages, src, dst):
                # tree-mapped so a QuantPages pool copies its scale blocks
                # together with the int8 value blocks (scales share the
                # pools' leading (layers, blocks) layout)
                return jax.tree.map(lambda p: p.at[:, dst].set(p[:, src]),
                                    pages)
            fn = jax.jit(_copy, donate_argnums=self._donate_argnums((0,)))
            self._cow_many_fns[n] = fn
        self.pages = fn(self.pages, jnp.asarray(src), jnp.asarray(dst))
        self._tables_dev = None
        for phys, _ in todo:
            self._release_block(phys)  # sole-ref cached sources go idle...
        self._enforce_retention()      # ...so the knob's bound applies here
        self.cow_copies += len(todo)
        self.cow_calls += 1
        return len(todo)

    def ensure_writable(self, slot: int, start: int, n_tokens: int = 1
                        ) -> int:
        """COW every block the write ``[start, start + n_tokens)`` touches
        that the slot does not exclusively own.  Cheap host check in the
        common case; multi-block writes coalesce their copies into one
        batched ``cow_blocks`` dispatch.  Returns the blocks copied."""
        if not self._cached and not (self._block_refs > 1).any():
            return 0
        lo = max(0, start) // self.block_size
        hi = max(0, start + n_tokens - 1) // self.block_size
        pairs = [(slot, logical)
                 for logical in range(lo, min(hi, self.blocks_per_slot - 1)
                                      + 1)
                 if self._block_tables[slot][logical] != self.trash_block]
        return self.cow_blocks(pairs)

    def block_tables(self) -> np.ndarray:
        """(capacity, blocks_per_slot) logical->physical block map."""
        return self._block_tables.copy()

    def occupancy(self) -> np.ndarray:
        return self._occ.copy()

    def device_block_tables(self) -> jnp.ndarray:
        """Device-resident block table, re-uploaded only after an alloc or
        free — steady-state decode steps pay no host copy or transfer."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self._block_tables)
        return self._tables_dev

    def device_occupancy(self) -> jnp.ndarray:
        if self._occ_dev is None:
            self._occ_dev = jnp.asarray(self._occ)
        return self._occ_dev

    @property
    def live(self) -> int:
        return int(self._occ.sum())

    def slot_bytes(self, prompt_len: int) -> int:
        """Bytes an admission actually writes: the prompt's pages (block-
        granular — whole blocks are the scatter unit) plus the slot's
        fixed state — NOT the live batch (which is never copied)."""
        blocks = self.blocks_for(max(1, prompt_len))
        return (blocks * self.block_size * self.token_bytes
                + self.state_slot_bytes)

    def chunk_bytes(self, n_tokens: int) -> int:
        """Bytes one chunked-prefill call writes: exactly the chunk's
        token rows (the multi-token ``append_rows`` scatter is row-
        granular, not block-granular) plus the slot's fixed state row."""
        return n_tokens * self.token_bytes + self.state_slot_bytes

    # ------------------------------------------------------------------
    # admission write path
    # ------------------------------------------------------------------
    @staticmethod
    def _donate_argnums(nums: Tuple[int, ...]) -> Tuple[int, ...]:
        """Donate the arena's device buffers so XLA updates pages/state in
        place instead of re-materializing the pool every call (CPU has no
        donation support, so skip it there to avoid per-compile warnings)."""
        return nums if jax.default_backend() != "cpu" else ()

    def write_prefill(self, slot: int, cache: Cache,
                      prompt_len: int) -> int:
        """Scatter one freshly prefilled single-request cache (batch 1,
        seq padded to ``slot_tokens``) into the slot's pages and state row.
        Only the blocks the prompt occupies are written — positions past
        the prompt are garbage until ``append_rows`` reaches them, and the
        per-slot ``len`` masks them everywhere.  Returns the bytes written
        (admission-copy accounting); one compile per distinct block count.
        """
        n_blocks = self.blocks_for(max(1, prompt_len))
        fn = self._write_fns.get(n_blocks)
        if fn is None:
            fn = jax.jit(functools.partial(self._write_prefill_impl,
                                           n_blocks=n_blocks),
                         donate_argnums=self._donate_argnums((0, 1, 2)))
            self._write_fns[n_blocks] = fn
        self.pages, self.state, self.lens = fn(
            self.pages, self.state, self.lens, cache,
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(self._block_tables[slot][:n_blocks], jnp.int32),
            jnp.asarray(prompt_len, jnp.int32))
        return self.slot_bytes(prompt_len)

    def _write_prefill_impl(self, pages, state, lens, cache, slot, bt_row,
                            plen, *, n_blocks):
        leaves = jax.tree.leaves(cache)
        new_pages, new_state = list(pages), list(state)
        pi = si = 0
        cache_len = None
        for leaf, tag in zip(leaves, self._tags):
            if tag == _LEN and cache_len is None:
                # trust the model's own emitted length (e.g. VLM prefills
                # count their image prefix on top of the text prompt)
                cache_len = jnp.asarray(leaf, jnp.int32).reshape(-1)[0]
            if tag == _PAGED:
                A0, _, S, *rest = leaf.shape
                blocks = leaf[:, 0, :n_blocks * self.block_size].reshape(
                    A0, n_blocks, self.block_size, *rest)
                if _is_quant(pages[pi]):
                    qv, qs = quantize(blocks)
                    new_pages[pi] = QuantPages(
                        pages[pi].values.at[:, bt_row].set(qv),
                        pages[pi].scales.at[:, bt_row].set(qs))
                else:
                    new_pages[pi] = pages[pi].at[:, bt_row].set(
                        blocks.astype(pages[pi].dtype))
                pi += 1
            elif tag == _STATE:
                new_state[si] = state[si].at[:, slot].set(
                    leaf[:, 0].astype(state[si].dtype))
                si += 1
        if cache_len is None:
            cache_len = plen
        return new_pages, new_state, lens.at[slot].set(cache_len)

    # ------------------------------------------------------------------
    # pure helpers for the fused decode step (jit-safe, no host state)
    # ------------------------------------------------------------------
    def dense_view(self, pages: Sequence[jnp.ndarray],
                   block_tables: jnp.ndarray) -> List[jnp.ndarray]:
        """Gather each page pool through the block table into a contiguous
        ``(layers, B, slot_tokens, ...)`` view (``B`` = the table's row
        count).  NOT the hot path anymore: the attention families' paged-
        NATIVE steps (``decode_step_paged`` / ``prefill_chunk_paged``)
        read K/V in place through the table, so this full materialization
        survives only as (a) the fallback for families/configs without a
        paged-native step (pure-SSM state caches, ring sliding-window
        layouts) and (b) the test/benchmark oracle the zero-gather path is
        verified bit-identical against.  A QuantPages pool gathers values
        and scales through the same table and dequantizes to the leaf's
        original dtype — the fallback sees exactly the float view the
        quantized kernels compute in-register."""
        B = block_tables.shape[0]
        out = []
        for p, dt in zip(pages, self._paged_dtypes):
            A0, _, bs, *rest = p.shape
            if _is_quant(p):
                g = dequantize(p.values[:, block_tables],
                               p.scales[:, block_tables], dt)
            else:
                g = p[:, block_tables]    # (A0, B, nblk, bs, *rest)
            out.append(g.reshape(A0, B, self.slot_tokens, *rest))
        return out

    def assemble(self, dense: Sequence[jnp.ndarray],
                 state: Sequence[jnp.ndarray],
                 lens: jnp.ndarray) -> Cache:
        """Rebuild the family's cache pytree (per-slot lens everywhere)."""
        leaves, di, si = [], iter(dense), iter(state)
        for tag, dt in zip(self._tags, self._dtypes):
            if tag == _LEN:
                leaves.append(lens.astype(dt))
            elif tag == _PAGED:
                leaves.append(next(di))
            else:
                leaves.append(next(si))
        return jax.tree.unflatten(self._treedef, leaves)

    def disassemble(self, cache: Cache) -> Tuple[List[jnp.ndarray],
                                                 List[jnp.ndarray]]:
        # QuantPages pools ride the paged-native steps as single cache
        # leaves, so flatten with them intact (a bare jax.tree.leaves would
        # split them into values + scales and misalign the tag zip)
        leaves = jax.tree.flatten(
            cache, is_leaf=lambda x: isinstance(x, QuantPages))[0]
        dense, state = [], []
        for leaf, tag in zip(leaves, self._tags):
            if tag == _PAGED:
                dense.append(leaf)
            elif tag == _STATE:
                state.append(leaf)
        return dense, state

    def append_rows(self, pages: Sequence[jnp.ndarray],
                    dense_new: Sequence[jnp.ndarray], lens: jnp.ndarray,
                    live: jnp.ndarray, block_tables: jnp.ndarray, *,
                    n_tokens: int = 1,
                    valid_tokens: Optional[jnp.ndarray] = None
                    ) -> List[jnp.ndarray]:
        """``arena.append``: write each live slot's newly produced cache
        tokens back to its physical pages, in place.

        Generalizes from the fused decode step's single-token append
        (``n_tokens=1``: one row per slot at position ``lens``) to the
        chunked-prefill multi-token append: ``n_tokens`` consecutive rows
        per slot starting at ``lens``, of which only the first
        ``valid_tokens`` (per slot, defaults to all) are real — this is
        ``write_prefill``'s offset/partial mode, keyed off the block table
        so chunk starts need no block alignment.  Rows of dead slots and
        padding rows past ``valid_tokens`` route to the trash block, so
        the scatter stays branch-free and shape-stable.
        """
        cap = lens.shape[0]
        bs = self.block_size
        offs = jnp.arange(n_tokens)                       # (T,)
        pos = jnp.clip(lens[:, None] + offs[None], 0,
                       self.slot_tokens - 1)              # (cap, T)
        blk = jnp.take_along_axis(block_tables, pos // bs, axis=1)
        flat = blk * bs + pos % bs
        ok = live[:, None]
        if valid_tokens is not None:
            ok = ok & (offs[None] < valid_tokens[:, None])
        flat = jnp.where(ok, flat, self.trash_block * bs).reshape(-1)
        out = []
        for p, d in zip(pages, dense_new):
            A0, P1, _, *rest = p.shape
            idx = pos.reshape(1, cap, n_tokens, *([1] * len(rest)))
            row = jnp.take_along_axis(d, idx, axis=2)     # (A0, cap, T, ...)
            if _is_quant(p):
                # fused scale update: the fresh float rows quantize on
                # insert; int8 rows and their scales land through the same
                # flat scatter, so the pool only ever holds quantized blocks
                qv, qs = quantize(row)
                pfv = p.values.reshape(A0, P1 * bs, *rest)
                pfv = pfv.at[:, flat].set(
                    qv.reshape(A0, cap * n_tokens, *rest))
                pfs = p.scales.reshape(A0, P1 * bs, *rest[:-1])
                pfs = pfs.at[:, flat].set(
                    qs.reshape(A0, cap * n_tokens, *rest[:-1]))
                out.append(QuantPages(pfv.reshape(p.values.shape),
                                      pfs.reshape(p.scales.shape)))
                continue
            pf = p.reshape(A0, P1 * bs, *rest)
            pf = pf.at[:, flat].set(
                row.reshape(A0, cap * n_tokens, *rest).astype(p.dtype))
            out.append(pf.reshape(p.shape))
        return out

    def merge_state(self, state: Sequence[jnp.ndarray],
                    state_new: Sequence[jnp.ndarray],
                    live: jnp.ndarray) -> List[jnp.ndarray]:
        """Commit updated per-slot state only for live slots (dead slots
        must not absorb the masked step's garbage)."""
        out = []
        for old, new in zip(state, state_new):
            mask = live.reshape(1, self.capacity,
                                *([1] * (old.ndim - 2)))
            out.append(jnp.where(mask, new.astype(old.dtype), old))
        return out
