"""Live serving engine: continuous-batching decode over persistent slots,
driven by an EPARA ParallelPlan.

``ServiceRuntime`` owns one service's params and its DP replica groups.
The default ``mode="continuous"`` keeps a persistent in-flight batch of
decode slots per group; each ``step()``:

  (a) **evicts** slots whose request hit EOS or its own ``max_new_tokens``
      (``kvcache.select_slots`` compacts the cache batch axis),
  (b) **admits** queued requests from the BS/MF composer into the freed
      slots (``compose(limit=free)``), prefilling each admission on its
      own — no cross-request padding — and merging the fresh cache into
      the live batch with ``kvcache.merge``,
  (c) runs **one fused decode step** for every occupied slot, with
      per-slot ``len`` vectors (the decode kernels mask per-batch
      ``cache_len``) and masked sampling for slots that finished at
      admission time.

Requests therefore decode exactly as long as they individually need, new
arrivals join mid-decode without waiting for a batch to drain, and every
result carries its own prefill time and admit→finish wall time.  The
pre-slot run-to-completion path is preserved behind ``mode="sync"`` so the
two can be compared (see benchmarks/continuous_batching.py); both modes
produce identical greedy tokens for identically padded prompts.

Request-level DP round-robins admissions across groups (sticky for
stateful archs).  The same engine object backs the CPU examples (reduced
configs) and, via pjit'd step functions passed in by the launcher, the
mesh deployment.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import DPGroupRouter, ParallelPlan
from repro.models.config import ModelConfig
from repro.models.registry import ModelApi, model_api

from . import kvcache
from .batching import ComposedBatch, MFComposer, QueuedItem, make_composer
from .sampler import SamplerConfig, sample


@dataclasses.dataclass
class GenerationRequest:
    rid: int
    tokens: np.ndarray               # prompt (L,) int32
    max_new_tokens: int = 16
    stream: int = 0
    extras: Optional[Dict[str, Any]] = None   # e.g. image/frame embeddings
    submitted_s: float = 0.0
    eos_token: Optional[int] = None  # evict the slot early on this token


@dataclasses.dataclass
class GenerationResult:
    rid: int
    tokens: np.ndarray               # generated ids (n,)
    prefill_s: float                 # this request's own prefill wall time
    decode_s: float                  # admit→finish wall time (continuous)
    group: int
    admitted_s: float = 0.0          # logical clock at admission
    finished_s: float = 0.0          # logical clock at eviction
    decode_steps: int = 0            # fused steps this request took part in


class _Slot:
    """One in-flight request occupying a decode slot."""
    __slots__ = ("req", "emitted", "done", "prefill_s", "admit_wall",
                 "decode_start_wall", "finish_wall", "admitted_s", "steps")

    def __init__(self, req: GenerationRequest, first_token: int,
                 prefill_s: float, admit_wall: float, admitted_s: float):
        self.req = req
        self.emitted: List[int] = [first_token]
        self.prefill_s = prefill_s
        self.admit_wall = admit_wall
        self.decode_start_wall = admit_wall + prefill_s
        self.finish_wall = 0.0
        self.admitted_s = admitted_s
        self.steps = 0
        self.done = (len(self.emitted) >= req.max_new_tokens
                     or (req.eos_token is not None
                         and first_token == req.eos_token))
        if self.done:
            self.finish_wall = self.decode_start_wall

    def push(self, token: int) -> None:
        self.emitted.append(token)
        if (len(self.emitted) >= self.req.max_new_tokens
                or (self.req.eos_token is not None
                    and token == self.req.eos_token)):
            self.done = True
            self.finish_wall = time.perf_counter()


class _GroupState:
    """Persistent in-flight batch of one DP replica group."""
    __slots__ = ("cache", "slots")

    def __init__(self):
        self.cache = None
        self.slots: List[_Slot] = []

    @property
    def live(self) -> int:
        return len(self.slots)


class ServiceRuntime:
    """One deployed service: params + plan + DP groups of decode slots."""

    def __init__(self, cfg: ModelConfig, params, plan: ParallelPlan, *,
                 prefill_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 impl: Optional[str] = None, mode: str = "continuous"):
        if mode not in ("continuous", "sync"):
            raise ValueError(f"mode must be continuous|sync, got {mode!r}")
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.mode = mode
        self.api: ModelApi = model_api(cfg)
        self.router = DPGroupRouter(plan)
        self.composer = make_composer(plan)
        self.sampler = sampler
        self._key = jax.random.PRNGKey(seed)
        self.groups: Dict[int, _GroupState] = {
            g: _GroupState() for g in range(max(1, plan.dp))}
        self.decode_steps = 0        # fused decode invocations (all groups)
        api = self.api

        if prefill_fn is None:
            prefill_fn = jax.jit(
                lambda p, b, cs: api.prefill(p, cfg, b, cache_size=cs,
                                             impl=impl),
                static_argnums=(2,))
        if decode_fn is None:
            decode_fn = jax.jit(
                lambda p, t, c: api.decode_step(p, cfg, t, c, impl=impl))
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn

    # -- queue ------------------------------------------------------------
    def submit(self, req: GenerationRequest, now: float = 0.0) -> None:
        self.composer.add(QueuedItem(payload=req, stream=req.stream,
                                     enqueued_s=now, rid=req.rid))

    def pending(self) -> int:
        return len(self.composer)

    def in_flight(self) -> int:
        return sum(g.live for g in self.groups.values())

    # -- shared helpers ---------------------------------------------------
    def _pad_prompts(self, reqs: Sequence[GenerationRequest]):
        L = max(len(r.tokens) for r in reqs)
        toks = np.zeros((len(reqs), L), np.int32)
        lens = np.zeros((len(reqs),), np.int32)
        for i, r in enumerate(reqs):
            toks[i, L - len(r.tokens):] = r.tokens   # left-pad
            lens[i] = len(r.tokens)
        return jnp.asarray(toks), lens

    def _build_batch(self, reqs: Sequence[GenerationRequest], toks):
        batch: Dict[str, Any] = {"tokens": toks}
        if self.cfg.family in ("audio", "vlm"):
            embs = [r.extras["embeddings"] for r in reqs]
            batch["embeddings"] = jnp.asarray(np.stack(embs))
        return batch

    def _sample(self, logits, live=None):
        self._key, sub = jax.random.split(self._key)
        return sample(logits, sub, self.sampler, live=live)

    # ------------------------------------------------------------------
    # continuous mode: slot admit / fused decode / evict
    # ------------------------------------------------------------------
    def _free_slots(self) -> int:
        return sum(max(0, self.plan.bs - g.live)
                   for g in self.groups.values())

    def _evict(self, group: int, state: _GroupState,
               now: float) -> List[GenerationResult]:
        """(a) Release every slot whose request finished; compact the
        cache batch axis with select_slots."""
        if not state.slots:
            return []
        keep = [i for i, s in enumerate(state.slots) if not s.done]
        if len(keep) == len(state.slots):
            return []
        results = []
        for s in state.slots:
            if not s.done:
                continue
            results.append(GenerationResult(
                rid=s.req.rid, tokens=np.asarray(s.emitted, np.int32),
                prefill_s=s.prefill_s,
                decode_s=max(0.0, s.finish_wall - s.decode_start_wall),
                group=group, admitted_s=s.admitted_s, finished_s=now,
                decode_steps=s.steps))
        state.slots = [state.slots[i] for i in keep]
        state.cache = (kvcache.select_slots(state.cache, keep)
                       if keep else None)
        return results

    def _admit_one(self, req: GenerationRequest, group: int,
                   state: _GroupState, now: float) -> None:
        """(b) Prefill one admission on its own (no cross-request padding)
        and merge its cache into the group's live batch."""
        t0 = time.perf_counter()
        toks, _ = self._pad_prompts([req])
        batch = self._build_batch([req], toks)
        cache_size = int(toks.shape[1] + req.max_new_tokens)
        logits, cache = self.prefill_fn(self.params, batch, cache_size)
        first = int(np.asarray(self._sample(logits))[0])
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        state.slots.append(_Slot(req, first, prefill_s=t1 - t0,
                                 admit_wall=t0, admitted_s=now))
        cache = kvcache.with_lens(cache, kvcache.lens(cache))
        state.cache = (cache if state.cache is None
                       else kvcache.merge([state.cache, cache]))

    def _route_admission(self, item: QueuedItem) -> Optional[int]:
        """Pick a DP group with a free slot; sticky sessions must land on
        their pinned group or wait."""
        g = self.router.route(session=item.stream)
        if self.groups[g].live < self.plan.bs:
            return g
        if self.plan.sticky and item.stream:
            return None          # session pinned to a full group: requeue
        for alt, state in self.groups.items():
            if state.live < self.plan.bs:
                return alt
        return None

    def _admit(self, now: float, max_wait_s: float) -> None:
        free = self._free_slots()
        if free <= 0 or not len(self.composer):
            return
        if isinstance(self.composer, MFComposer):
            composed = self.composer.compose(now=now, max_wait_s=max_wait_s,
                                             limit=free)
        else:
            composed = self.composer.compose(limit=free)
        if composed is None:
            return
        unplaced = []
        for item in composed.items:
            g = self._route_admission(item)
            if g is None:
                unplaced.append(item)
                continue
            self._admit_one(item.payload, g, self.groups[g], now)
        for item in reversed(unplaced):   # push_front in reverse keeps FIFO
            self.composer.push_front(item)

    def _decode_group(self, state: _GroupState) -> None:
        """(c) One fused decode step over every occupied slot."""
        if not state.slots:
            return
        live = np.array([not s.done for s in state.slots])
        if not live.any():
            return               # everything awaits eviction
        cur = jnp.asarray([s.emitted[-1] if not s.done else 0
                           for s in state.slots], jnp.int32)
        logits, state.cache = self.decode_fn(self.params, cur, state.cache)
        toks = np.asarray(self._sample(logits, live=jnp.asarray(live)))
        self.decode_steps += 1
        for i, slot in enumerate(state.slots):
            if slot.done:
                continue
            slot.steps += 1
            slot.push(int(toks[i]))

    def _step_continuous(self, now: float,
                         max_wait_s: float) -> List[GenerationResult]:
        results: List[GenerationResult] = []
        for group, state in self.groups.items():
            results.extend(self._evict(group, state, now))
        self._admit(now, max_wait_s)
        for state in self.groups.values():
            self._decode_group(state)
        return results

    # ------------------------------------------------------------------
    # sync mode: run-to-completion batches (the pre-slot baseline)
    # ------------------------------------------------------------------
    def run_batch(self, composed: ComposedBatch, *,
                  now: float = 0.0) -> List[GenerationResult]:
        reqs = [item.payload for item in composed.items]
        group = self.router.route(session=reqs[0].stream)
        toks, lens = self._pad_prompts(reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        cache_size = int(toks.shape[1] + max_new)

        t0 = time.perf_counter()
        batch = self._build_batch(reqs, toks)
        logits, cache = self.prefill_fn(self.params, batch, cache_size)
        logits = jax.block_until_ready(logits)
        t1 = time.perf_counter()

        outs = []
        cur = self._sample(logits)
        outs.append(np.asarray(cur))
        for _ in range(max_new - 1):
            logits, cache = self.decode_fn(self.params, cur, cache)
            cur = self._sample(logits)
            outs.append(np.asarray(cur))
            self.decode_steps += 1
        jax.block_until_ready(cur)
        t2 = time.perf_counter()

        gen = np.stack(outs, axis=1)  # (B, max_new)
        results = []
        for i, r in enumerate(reqs):
            # sync mode charges the batch-wide decode time to every member
            # (the very distortion the slot path fixes)
            results.append(GenerationResult(
                rid=r.rid, tokens=gen[i, :r.max_new_tokens],
                prefill_s=t1 - t0, decode_s=t2 - t1, group=group,
                admitted_s=now, finished_s=now,
                decode_steps=max_new - 1))
        return results

    def _step_sync(self, now: float,
                   max_wait_s: float) -> List[GenerationResult]:
        if isinstance(self.composer, MFComposer):
            composed = self.composer.compose(now=now, max_wait_s=max_wait_s)
        else:
            composed = self.composer.compose()
        if composed is None:
            return []
        return self.run_batch(composed, now=now)

    # ------------------------------------------------------------------
    def step(self, now: float = 0.0,
             max_wait_s: float = float("inf")) -> List[GenerationResult]:
        """Advance the data plane by one scheduling round.

        Continuous mode: evict / admit / one fused decode step.  Sync
        mode: compose one batch (BS or MF semantics) and run it to
        completion."""
        if self.mode == "sync":
            return self._step_sync(now, max_wait_s)
        return self._step_continuous(now, max_wait_s)

    def drain(self, now: float = 0.0,
              max_wait_s: float = 0.0) -> List[GenerationResult]:
        """Step until queue and slots are empty; returns all results."""
        out: List[GenerationResult] = []
        while self.pending() or self.in_flight():
            before = (self.pending(), self.in_flight(), self.decode_steps)
            res = self.step(now=now, max_wait_s=max_wait_s)
            out.extend(res)
            if (self.pending(), self.in_flight(),
                    self.decode_steps) == before and not res:
                break            # no progress possible (e.g. empty compose)
        return out


class EparaServingEngine:
    """Multi-service front door: submits requests to ServiceRuntimes by
    service name.  Placement/offload decisions come from the control plane
    (see examples/serve_cluster.py); this class is the data plane."""

    def __init__(self):
        self.runtimes: Dict[str, ServiceRuntime] = {}
        self._results: List[GenerationResult] = []

    def deploy(self, name: str, runtime: ServiceRuntime) -> None:
        self.runtimes[name] = runtime

    def submit(self, service: str, req: GenerationRequest,
               now: float = 0.0) -> None:
        self.runtimes[service].submit(req, now)

    def step(self, now: float = 0.0,
             max_wait_s: float = 0.0) -> List[GenerationResult]:
        """One scheduling round across every deployed runtime."""
        out: List[GenerationResult] = []
        for rt in self.runtimes.values():
            out.extend(rt.step(now=now, max_wait_s=max_wait_s))
        self._results.extend(out)
        return out

    def drain(self, now: float = 0.0) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        for rt in self.runtimes.values():
            while rt.pending() or rt.in_flight():
                before = (rt.pending(), rt.in_flight(), rt.decode_steps)
                res = rt.step(now=now, max_wait_s=0.0)
                out.extend(res)
                if (rt.pending(), rt.in_flight(),
                        rt.decode_steps) == before and not res:
                    break
        self._results.extend(out)
        return out
